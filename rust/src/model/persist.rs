//! Schema-versioned JSONL persistence for formal [`Trace`]s: what
//! `--record-trace` writes and what `pscnf check <trace>` reads.
//!
//! Line 1 is a header object (`schema`, event/edge counts); every
//! following line is one record in trace order — data ops, sync ops,
//! then synchronization-order edges:
//!
//! ```text
//! {"edges":1,"events":3,"kind":"pscnf-trace","schema":1}
//! {"access":"w","end":10,"file":0,"rank":0,"start":0,"t":"data"}
//! {"file":0,"kind":"commit","rank":0,"t":"sync"}
//! {"access":"r","end":10,"file":0,"rank":1,"start":0,"t":"data"}
//! {"from":1,"t":"so","to":2}
//! ```
//!
//! Event ids are implicit (line order = [`Trace::push`] order), so a
//! loaded trace is bit-identical to the recorded one: same events, same
//! so-edges, same ids. Sync kinds serialize via their canonical
//! [`SyncKind`] display form (`commit`, `session_open`, `MPI_File_sync`,
//! `custom#7`, ...); the parser here is its exact inverse — deliberately
//! NOT the config-file grammar of `policy::parse_sync_kind`.

use super::op::{Access, StorageOp, SyncKind};
use super::trace::Trace;
use crate::interval::Range;
use crate::util::json::Json;

/// Bump when the line format changes incompatibly; `from_jsonl` rejects
/// anything else so stale recordings fail loudly, not subtly.
pub const TRACE_SCHEMA: u64 = 1;

fn sync_kind_to_str(kind: SyncKind) -> String {
    kind.to_string()
}

fn sync_kind_from_str(s: &str) -> Result<SyncKind, String> {
    match s {
        "commit" => Ok(SyncKind::Commit),
        "session_open" => Ok(SyncKind::SessionOpen),
        "session_close" => Ok(SyncKind::SessionClose),
        "MPI_File_open" => Ok(SyncKind::MpiFileOpen),
        "MPI_File_close" => Ok(SyncKind::MpiFileClose),
        "MPI_File_sync" => Ok(SyncKind::MpiFileSync),
        other => match other.strip_prefix("custom#") {
            Some(id) => id
                .parse::<u16>()
                .map(SyncKind::Custom)
                .map_err(|_| format!("bad custom sync kind {other:?}")),
            None => Err(format!("unknown sync kind {other:?}")),
        },
    }
}

/// Serialize a trace to JSONL (one JSON object per line, trailing
/// newline). Deterministic: `Json` objects dump with sorted keys.
pub fn to_jsonl(trace: &Trace) -> String {
    let mut out = String::new();
    let mut header = Json::obj();
    header
        .set("schema", TRACE_SCHEMA)
        .set("kind", "pscnf-trace")
        .set("events", trace.len())
        .set("edges", trace.so_edges().len());
    out.push_str(&header.dump());
    out.push('\n');
    for ev in trace.events() {
        let mut line = Json::obj();
        match ev.op {
            StorageOp::Data { access, file, range } => {
                line.set("t", "data")
                    .set("rank", ev.rank)
                    .set("access", if access == Access::Write { "w" } else { "r" })
                    .set("file", file)
                    .set("start", range.start)
                    .set("end", range.end);
            }
            StorageOp::Sync { kind, file } => {
                line.set("t", "sync")
                    .set("rank", ev.rank)
                    .set("kind", sync_kind_to_str(kind))
                    .set("file", file);
            }
        }
        out.push_str(&line.dump());
        out.push('\n');
    }
    for &(from, to) in trace.so_edges() {
        let mut line = Json::obj();
        line.set("t", "so").set("from", from).set("to", to);
        out.push_str(&line.dump());
        out.push('\n');
    }
    out
}

fn get_u64(obj: &Json, key: &str, what: &str) -> Result<u64, String> {
    obj.get(key)
        .and_then(Json::as_f64)
        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
        .map(|v| v as u64)
        .ok_or_else(|| format!("{what}: missing or non-integer {key:?}"))
}

fn get_str<'j>(obj: &'j Json, key: &str, what: &str) -> Result<&'j str, String> {
    obj.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| format!("{what}: missing string {key:?}"))
}

/// Parse a JSONL trace. Errors carry the offending line number.
pub fn from_jsonl(text: &str) -> Result<Trace, String> {
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
    let (_, header_line) = lines.next().ok_or("empty trace file")?;
    let header = Json::parse(header_line).map_err(|e| format!("line 1 (header): {e}"))?;
    let schema = get_u64(&header, "schema", "header")?;
    if schema != TRACE_SCHEMA {
        return Err(format!(
            "unsupported trace schema {schema} (this build reads schema {TRACE_SCHEMA})"
        ));
    }
    let n_events = get_u64(&header, "events", "header")? as usize;
    let n_edges = get_u64(&header, "edges", "header")? as usize;

    let mut trace = Trace::new();
    let mut edges_seen = 0usize;
    for (idx, line) in lines {
        let what = format!("line {}", idx + 1);
        let rec = Json::parse(line).map_err(|e| format!("{what}: {e}"))?;
        match get_str(&rec, "t", &what)? {
            "data" => {
                if edges_seen > 0 {
                    return Err(format!("{what}: event record after so-edge records"));
                }
                let rank = get_u64(&rec, "rank", &what)? as u32;
                let file = get_u64(&rec, "file", &what)? as u32;
                let start = get_u64(&rec, "start", &what)?;
                let end = get_u64(&rec, "end", &what)?;
                if end < start {
                    return Err(format!("{what}: end {end} < start {start}"));
                }
                let range = Range::new(start, end);
                let op = match get_str(&rec, "access", &what)? {
                    "w" => StorageOp::write(file, range),
                    "r" => StorageOp::read(file, range),
                    other => return Err(format!("{what}: bad access {other:?}")),
                };
                trace.push(rank, op);
            }
            "sync" => {
                if edges_seen > 0 {
                    return Err(format!("{what}: event record after so-edge records"));
                }
                let rank = get_u64(&rec, "rank", &what)? as u32;
                let file = get_u64(&rec, "file", &what)? as u32;
                let kind = sync_kind_from_str(get_str(&rec, "kind", &what)?)
                    .map_err(|e| format!("{what}: {e}"))?;
                trace.push(rank, StorageOp::sync(kind, file));
            }
            "so" => {
                let from = get_u64(&rec, "from", &what)? as usize;
                let to = get_u64(&rec, "to", &what)? as usize;
                if from >= trace.len() || to >= trace.len() {
                    return Err(format!("{what}: so edge {from}->{to} out of range"));
                }
                trace.add_so(from, to);
                edges_seen += 1;
            }
            other => return Err(format!("{what}: unknown record type {other:?}")),
        }
    }
    if trace.len() != n_events || edges_seen != n_edges {
        return Err(format!(
            "truncated trace: header promises {n_events} events / {n_edges} edges, found {} / {}",
            trace.len(),
            edges_seen
        ));
    }
    Ok(trace)
}

/// Write a trace to `path` (JSONL).
pub fn save(trace: &Trace, path: &std::path::Path) -> Result<(), String> {
    crate::util::ensure_parent_dir(path)?;
    std::fs::write(path, to_jsonl(trace)).map_err(|e| format!("write {}: {e}", path.display()))
}

/// Read a trace from `path` (JSONL).
pub fn load(path: &std::path::Path) -> Result<Trace, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    from_jsonl(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::new();
        let w = t.push(0, StorageOp::write(0, Range::new(0, 10)));
        let c = t.push(0, StorageOp::sync(SyncKind::Commit, 0));
        let r = t.push(1, StorageOp::read(0, Range::new(5, 15)));
        t.push(2, StorageOp::sync(SyncKind::Custom(7), 3));
        t.add_so(c, r);
        t.add_so(w, r);
        t
    }

    #[test]
    fn round_trip_is_bit_identical() {
        let t = sample();
        let text = to_jsonl(&t);
        let back = from_jsonl(&text).unwrap();
        assert_eq!(back.events(), t.events());
        assert_eq!(back.so_edges(), t.so_edges());
        assert_eq!(to_jsonl(&back), text, "serialize∘parse must be the identity on files");
    }

    #[test]
    fn sync_kind_strings_invert_display() {
        for kind in [
            SyncKind::Commit,
            SyncKind::SessionOpen,
            SyncKind::SessionClose,
            SyncKind::MpiFileOpen,
            SyncKind::MpiFileClose,
            SyncKind::MpiFileSync,
            SyncKind::Custom(42),
        ] {
            assert_eq!(sync_kind_from_str(&sync_kind_to_str(kind)), Ok(kind));
        }
        assert!(sync_kind_from_str("mpi_file_open").is_err(), "config grammar is not this grammar");
    }

    #[test]
    fn wrong_schema_is_rejected() {
        let t = sample();
        let text = to_jsonl(&t).replacen("\"schema\":1", "\"schema\":2", 1);
        let err = from_jsonl(&text).unwrap_err();
        assert!(err.contains("unsupported trace schema 2"), "{err}");
    }

    #[test]
    fn truncation_and_garbage_are_rejected() {
        let t = sample();
        let text = to_jsonl(&t);
        let truncated: String =
            text.lines().take(t.len()).map(|l| format!("{l}\n")).collect();
        assert!(from_jsonl(&truncated).unwrap_err().contains("truncated"));
        assert!(from_jsonl("").is_err());
        assert!(from_jsonl("{\"schema\":1,\"events\":0,\"edges\":0}\nnot json\n").is_err());
    }
}
