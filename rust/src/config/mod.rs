//! Experiment configuration: testbed presets (device models), cluster
//! shape, and workload parameters, loadable from an INI-like file with
//! CLI overrides. serde/toml are unavailable offline, so the format is
//! deliberately simple:
//!
//! ```ini
//! # experiment.cfg
//! [cluster]
//! nodes = 16
//! ppn = 12
//! testbed = catalyst   # catalyst | expanse | hdd | pmem
//! engine_threads = 4   # windowed parallel event loop; results identical to 1
//!
//! [workload]
//! config = CC-R
//! fs = session
//! size = 8K
//! m = 10
//! seed = 7
//!
//! # A consistency model defined as DATA (no Rust change): registered
//! # into the model registry, runnable via `fs = lazy` and through the
//! # bench matrix (`pscnf bench --config ... --models lazy`).
//! [model.lazy]
//! publication = phase_end
//! acquisition = lifetime_snapshot
//! ```

use crate::fs::FsKind;
use crate::model::WriteAck;
use crate::sim::faults::parse_ns;
use crate::sim::{Cluster, FaultPlan, NetParams, ReplicaParams, ServerParams, SsdParams, UpfsParams};
use crate::util::cli::{ArgSpec, ParsedArgs};
use crate::util::units::parse_bytes;
use crate::workload::Config as TableConfig;
use std::collections::BTreeMap;

/// The single `>= 1` validator for run-shape knobs. Both spellings of
/// every knob — the CLI flag (`--engine-threads 0`) and the INI key
/// (`[cluster] engine_threads = 0`) — route through here, so they
/// report the *same* error text (they used to drift).
pub fn require_at_least_one(key: &str, v: usize) -> Result<usize, String> {
    if v == 0 {
        Err(format!("{key} must be >= 1"))
    } else {
        Ok(v)
    }
}

/// Parsed INI-ish file: section -> key -> value.
pub type Ini = BTreeMap<String, BTreeMap<String, String>>;

/// Parse the config text. Unknown sections/keys are preserved (callers
/// validate what they consume); syntax errors are reported with lines.
pub fn parse_ini(text: &str) -> Result<Ini, String> {
    let mut out: Ini = BTreeMap::new();
    let mut section = String::from("global");
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(stripped) = line.strip_prefix('[') {
            let name = stripped
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
            section = name.trim().to_string();
            out.entry(section.clone()).or_default();
        } else if let Some((k, v)) = line.split_once('=') {
            out.entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v.trim().to_string());
        } else {
            return Err(format!("line {}: expected `key = value`", lineno + 1));
        }
    }
    Ok(out)
}

/// Device-model preset (the paper's testbeds + ablation devices).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Testbed {
    Catalyst,
    Expanse,
    Hdd,
    Pmem,
}

impl Testbed {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "catalyst" => Ok(Testbed::Catalyst),
            "expanse" => Ok(Testbed::Expanse),
            "hdd" => Ok(Testbed::Hdd),
            "pmem" => Ok(Testbed::Pmem),
            other => Err(format!("unknown testbed `{other}`")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Testbed::Catalyst => "catalyst",
            Testbed::Expanse => "expanse",
            Testbed::Hdd => "hdd",
            Testbed::Pmem => "pmem",
        }
    }

    pub fn ssd(&self) -> SsdParams {
        match self {
            Testbed::Catalyst => SsdParams::catalyst(),
            Testbed::Expanse => SsdParams::expanse(),
            Testbed::Hdd => SsdParams::hdd(),
            Testbed::Pmem => SsdParams::pmem(),
        }
    }

    /// Build the simulated cluster for `nodes` nodes.
    pub fn cluster(&self, nodes: usize, seed: u64) -> Cluster {
        self.cluster_sharded(nodes, seed, 1)
    }

    /// Cluster whose metadata plane has `shards` shards (1 = the
    /// paper's single global server).
    pub fn cluster_sharded(&self, nodes: usize, seed: u64, shards: usize) -> Cluster {
        Cluster::new(
            nodes,
            self.ssd(),
            NetParams::ib_qdr(),
            ServerParams::catalyst_sharded(shards),
            UpfsParams::catalyst_lustre(),
            seed,
        )
    }
}

/// Full experiment spec assembled from file + CLI.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub testbed: Testbed,
    pub nodes: usize,
    pub ppn: usize,
    /// Metadata-plane shards (`[cluster] shards`); 1 = the paper's
    /// single global server.
    pub shards: usize,
    pub fs: FsKind,
    pub workload: TableConfig,
    pub access_size: u64,
    pub accesses_per_proc: usize,
    /// Shared files the dataset is striped over (`[workload] files`).
    pub files: usize,
    /// Windowed parallel event-loop width (`[cluster] engine_threads`);
    /// 1 = the serial loop. Any value yields byte-identical results —
    /// the knob only trades wall time, so it lives next to the cluster
    /// shape rather than the workload.
    pub engine_threads: usize,
    /// Deterministic fault schedule (`[faults]` section or `--faults`);
    /// empty = healthy run.
    pub faults: FaultPlan,
    /// Durability plane (`[replication]` section or `--replicas`):
    /// per-shard replica set and its geo-latency topology. `None` =
    /// single-copy metadata, bit-for-bit the historical fabric. The
    /// *ack mode* is not here — it is a property of the consistency
    /// model (`[model.<name>] write_ack`), so the same replica
    /// topology can be swept across ack policies.
    pub replication: Option<ReplicaParams>,
    /// `--write-ack`: sweep-style override of the model's own
    /// `write_ack` axis (`None` = the model decides). CLI/bench only —
    /// an INI model states its ack mode in its own `[model.<name>]`
    /// block, not here.
    pub write_ack: Option<WriteAck>,
    pub seed: u64,
}

impl Default for Experiment {
    fn default() -> Self {
        Self {
            testbed: Testbed::Catalyst,
            nodes: 4,
            ppn: 12,
            shards: 1,
            fs: FsKind::SESSION,
            workload: TableConfig::CcR,
            access_size: 8 << 10,
            accesses_per_proc: 10,
            files: 1,
            engine_threads: 1,
            faults: FaultPlan::new(),
            replication: None,
            write_ack: None,
            seed: 7,
        }
    }
}

impl Experiment {
    /// Overlay values from an INI file. `[model.<name>]` sections are
    /// registered into the model registry FIRST, so `[workload] fs`
    /// (and every later CLI flag) can name a model that exists only in
    /// this file.
    pub fn apply_ini(&mut self, ini: &Ini) -> Result<(), String> {
        FsKind::register_from_ini(ini)?;
        if let Some(cluster) = ini.get("cluster") {
            if let Some(v) = cluster.get("nodes") {
                self.nodes = v.parse().map_err(|e| format!("cluster.nodes: {e}"))?;
            }
            if let Some(v) = cluster.get("ppn") {
                self.ppn = v.parse().map_err(|e| format!("cluster.ppn: {e}"))?;
            }
            if let Some(v) = cluster.get("testbed") {
                self.testbed = Testbed::parse(v)?;
            }
            if let Some(v) = cluster.get("shards") {
                self.shards = require_at_least_one(
                    "shards",
                    v.parse().map_err(|e| format!("cluster.shards: {e}"))?,
                )?;
            }
            if let Some(v) = cluster.get("engine_threads") {
                self.engine_threads = require_at_least_one(
                    "engine_threads",
                    v.parse()
                        .map_err(|e| format!("cluster.engine_threads: {e}"))?,
                )?;
            }
        }
        if let Some(w) = ini.get("workload") {
            if let Some(v) = w.get("config") {
                self.workload = TableConfig::parse(v)?;
            }
            if let Some(v) = w.get("fs") {
                self.fs = FsKind::parse(v)?;
            }
            if let Some(v) = w.get("size") {
                self.access_size = parse_bytes(v)?;
            }
            if let Some(v) = w.get("m") {
                self.accesses_per_proc = v.parse().map_err(|e| format!("workload.m: {e}"))?;
            }
            if let Some(v) = w.get("seed") {
                self.seed = v.parse().map_err(|e| format!("workload.seed: {e}"))?;
            }
            if let Some(v) = w.get("files") {
                self.files = require_at_least_one(
                    "files",
                    v.parse().map_err(|e| format!("workload.files: {e}"))?,
                )?;
            }
        }
        if let Some(section) = ini.get("faults") {
            self.faults = FaultPlan::from_ini(section)?;
        }
        if let Some(section) = ini.get("replication") {
            self.replication = Some(replication_from_ini(section)?);
        }
        Ok(())
    }

    pub fn params(&self) -> crate::workload::WorkloadParams {
        self.workload
            .params(
                self.nodes,
                self.ppn,
                self.access_size,
                self.accesses_per_proc,
                self.seed,
            )
            .with_files(self.files)
    }

    pub fn cluster(&self) -> Cluster {
        self.testbed
            .cluster_sharded(self.nodes, self.seed ^ 0xC1A5, self.shards)
    }

    /// The driver-facing [`RunConfig`] this experiment implies.
    pub fn run_config(&self) -> RunConfig {
        RunConfig::new()
            .shards(self.shards)
            .engine_threads(self.engine_threads)
            .faults(self.faults.clone())
            .replication(self.replication.clone())
            .write_ack(self.write_ack)
    }
}

/// Parse a `[replication]` section. Starts from a latency preset
/// (`preset = near | far`, default `near`) and overlays explicit keys:
///
/// ```ini
/// [replication]
/// replicas = 2       # replica tiers per shard (>= 1)
/// preset = far       # near (same-row RTT) | far (cross-site RTT)
/// rtt = 500us        # nearest-tier round trip
/// tier_step = 2ms    # added RTT per further tier
/// bw = 1G            # replication-channel bandwidth, bytes/sec
/// ```
pub fn replication_from_ini(section: &BTreeMap<String, String>) -> Result<ReplicaParams, String> {
    let mut p = match section.get("preset").map(String::as_str) {
        None | Some("near") => ReplicaParams::near(),
        Some("far") => ReplicaParams::far(),
        Some(other) => {
            return Err(format!(
                "replication.preset: unknown `{other}` (expected near | far)"
            ))
        }
    };
    for (key, value) in section {
        match key.as_str() {
            "preset" => {}
            "replicas" => {
                p.replicas = require_at_least_one(
                    "replicas",
                    value.parse().map_err(|e| format!("replication.replicas: {e}"))?,
                )?;
            }
            "rtt" => p.rtt = parse_ns(value).map_err(|e| format!("replication.rtt: {e}"))?,
            "tier_step" => {
                p.tier_step = parse_ns(value).map_err(|e| format!("replication.tier_step: {e}"))?
            }
            "bw" => {
                let bw = parse_bytes(value).map_err(|e| format!("replication.bw: {e}"))?;
                if bw == 0 {
                    return Err("replication.bw must be positive".into());
                }
                p.bw = bw as f64;
            }
            other => return Err(format!("replication.{other}: unknown key")),
        }
    }
    Ok(p)
}

/// The one way to shape a driver run — replaces the historical
/// constructor sprawl (`new` / `new_with_data` / `new_sharded` /
/// `new_lazy` / `run_with_threads`, duplicated across the synthetic,
/// SCR, DL and bench drivers) with a single builder consumed by each
/// driver's `with_config` constructor and `run_cfg` entry point. The
/// default value reproduces `Driver::new(...).run(...)` exactly.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Metadata-plane shards (1 = the paper's single global server).
    pub shards: usize,
    /// Build per-rank FS layers on first touch instead of up front
    /// (streams million-rank states; implies a phantom fabric).
    pub lazy: bool,
    /// Track lengths/ownership only, no payload bytes (benchmark
    /// scale). `false` = byte-exact stores.
    pub phantom: bool,
    /// Windowed parallel event-loop width; 1 = the serial loop.
    /// Results are byte-identical for any value.
    pub engine_threads: usize,
    /// Deterministic fault schedule; empty = healthy run. A non-empty
    /// plan switches the fabric fault-aware, with the recovery mode
    /// derived from the model's [`crate::model::RecoveryObligation`].
    pub faults: FaultPlan,
    /// Override the FS-layer factory (differential tests stack extra
    /// layers); `None` = the policy-interpreted default layer.
    pub layers: Option<crate::workload::LazyMake>,
    /// Durability plane: replica set per metadata shard. `None` =
    /// single-copy fabric. The ack mode comes from the model's
    /// `write_ack` policy axis, resolved by the driver.
    pub replication: Option<ReplicaParams>,
    /// Override the model's `write_ack` axis for this run (`None` =
    /// the model decides). This is how `ablate_replication` sweeps ack
    /// modes across built-in models without registering variants.
    pub write_ack: Option<WriteAck>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            shards: 1,
            lazy: false,
            phantom: true,
            engine_threads: 1,
            faults: FaultPlan::new(),
            layers: None,
            replication: None,
            write_ack: None,
        }
    }
}

impl RunConfig {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    pub fn lazy(mut self, lazy: bool) -> Self {
        self.lazy = lazy;
        self
    }

    pub fn phantom(mut self, phantom: bool) -> Self {
        self.phantom = phantom;
        self
    }

    pub fn engine_threads(mut self, threads: usize) -> Self {
        self.engine_threads = threads;
        self
    }

    pub fn faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    pub fn layers(mut self, make: crate::workload::LazyMake) -> Self {
        self.layers = Some(make);
        self
    }

    pub fn replication(mut self, replication: Option<ReplicaParams>) -> Self {
        self.replication = replication;
        self
    }

    pub fn write_ack(mut self, write_ack: Option<WriteAck>) -> Self {
        self.write_ack = write_ack;
        self
    }
}

/// The run-shape argument block shared by `pscnf run` and `pscnf
/// bench`: one set of flag names, one parse, and the same validation
/// (via [`require_at_least_one`]) as the INI keys, so the CLI and
/// config-file spellings of a knob cannot report different errors.
/// `None` = the flag was not given (callers fall back to the config
/// file, then the registry/built-in default).
#[derive(Debug, Clone, Default)]
pub struct RunArgs {
    pub shards: Option<usize>,
    pub files: Option<usize>,
    pub engine_threads: Option<usize>,
    pub faults: Option<FaultPlan>,
    /// `--replicas N`: enable the durability plane with N replica
    /// tiers per shard (near preset unless the config file already
    /// chose a topology, which this count then overrides).
    pub replicas: Option<usize>,
    /// `--write-ack MODE`: override every selected model's durability
    /// ack axis for this run.
    pub write_ack: Option<WriteAck>,
}

impl RunArgs {
    /// Attach the shared flags to a subcommand spec. Empty-string
    /// defaults mean "not given" so provenance layering works without
    /// sentinel values like the historical `--engine-threads 0`.
    pub fn add_to_spec(spec: ArgSpec) -> ArgSpec {
        spec.opt(
            "shards",
            "N",
            Some(""),
            "metadata-plane shards; 1 = the paper's single server (empty = config/registry value)",
        )
        .opt(
            "files",
            "N",
            Some(""),
            "shared files the dataset is striped over (empty = config/registry value)",
        )
        .opt(
            "engine-threads",
            "N",
            Some(""),
            "windowed parallel event-loop width; results are byte-identical for any value \
             (empty = config/registry value)",
        )
        .opt(
            "faults",
            "PLAN",
            Some(""),
            "fault plan, e.g. `kill shard 0 at 2ms; restart shard 0 at 4ms` \
             (empty = config value / healthy)",
        )
        .opt(
            "replicas",
            "N",
            Some(""),
            "replica tiers per metadata shard; enables the durability plane \
             (empty = config value / single-copy)",
        )
        .opt(
            "write-ack",
            "MODE",
            Some(""),
            "override the model's write_ack axis: local_only | local_plus_one \
             | sync (empty = each model's own)",
        )
    }

    /// Extract the shared block from parsed CLI args.
    pub fn from_parsed(args: &ParsedArgs) -> Result<Self, String> {
        let knob = |flag: &str, key: &str| -> Result<Option<usize>, String> {
            match args.str(flag)? {
                "" => Ok(None),
                s => {
                    let v: usize = s.parse().map_err(|e| format!("--{flag}: {e}"))?;
                    require_at_least_one(key, v).map(Some)
                }
            }
        };
        let faults = match args.str("faults")? {
            "" => None,
            spec => Some(FaultPlan::parse_spec(spec).map_err(|e| format!("--faults: {e}"))?),
        };
        let write_ack = match args.str("write-ack")? {
            "" => None,
            mode => Some(WriteAck::parse(mode).map_err(|e| format!("--write-ack: {e}"))?),
        };
        Ok(Self {
            shards: knob("shards", "shards")?,
            files: knob("files", "files")?,
            engine_threads: knob("engine-threads", "engine_threads")?,
            faults,
            replicas: knob("replicas", "replicas")?,
            write_ack,
        })
    }

    /// Overlay onto an [`Experiment`] (CLI wins over whatever the
    /// experiment already holds — file value or built-in default).
    pub fn apply_to(&self, exp: &mut Experiment) {
        if let Some(v) = self.shards {
            exp.shards = v;
        }
        if let Some(v) = self.files {
            exp.files = v;
        }
        if let Some(v) = self.engine_threads {
            exp.engine_threads = v;
        }
        if let Some(p) = &self.faults {
            exp.faults = p.clone();
        }
        if let Some(n) = self.replicas {
            let mut params = exp.replication.clone().unwrap_or_else(ReplicaParams::near);
            params.replicas = n;
            exp.replication = Some(params);
        }
        if let Some(ack) = self.write_ack {
            exp.write_ack = Some(ack);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ini_parses_sections_comments() {
        let ini = parse_ini(
            "# top comment\n[cluster]\nnodes = 8 # inline\nppn=4\n\n[workload]\nfs = commit\n",
        )
        .unwrap();
        assert_eq!(ini["cluster"]["nodes"], "8");
        assert_eq!(ini["cluster"]["ppn"], "4");
        assert_eq!(ini["workload"]["fs"], "commit");
    }

    #[test]
    fn ini_rejects_bad_lines() {
        assert!(parse_ini("[cluster\n").is_err());
        assert!(parse_ini("justaword\n").is_err());
    }

    #[test]
    fn experiment_overlay() {
        let mut e = Experiment::default();
        let ini = parse_ini(
            "[cluster]\nnodes=16\ntestbed=expanse\n[workload]\nconfig=CS-R\nfs=commit\nsize=8M\nm=5\n",
        )
        .unwrap();
        e.apply_ini(&ini).unwrap();
        assert_eq!(e.nodes, 16);
        assert_eq!(e.testbed, Testbed::Expanse);
        assert_eq!(e.fs, FsKind::COMMIT);
        assert_eq!(e.access_size, 8 << 20);
        assert_eq!(e.accesses_per_proc, 5);
        let p = e.params();
        assert_eq!(p.n_w, 8);
        assert_eq!(p.n_r, 8);
    }

    #[test]
    fn shards_and_files_overlay() {
        let mut e = Experiment::default();
        assert_eq!(e.shards, 1);
        assert_eq!(e.files, 1);
        assert_eq!(e.engine_threads, 1);
        let ini =
            parse_ini("[cluster]\nshards=8\nengine_threads=4\n[workload]\nfiles=16\n").unwrap();
        e.apply_ini(&ini).unwrap();
        assert_eq!(e.shards, 8);
        assert_eq!(e.files, 16);
        assert_eq!(e.engine_threads, 4);
        assert_eq!(e.params().files, 16);
        assert_eq!(e.cluster().server.shard_count(), 8);
        // Zero is rejected for all three.
        assert!(Experiment::default()
            .apply_ini(&parse_ini("[cluster]\nshards=0\n").unwrap())
            .is_err());
        assert!(Experiment::default()
            .apply_ini(&parse_ini("[workload]\nfiles=0\n").unwrap())
            .is_err());
        assert!(Experiment::default()
            .apply_ini(&parse_ini("[cluster]\nengine_threads=0\n").unwrap())
            .is_err());
    }

    #[test]
    fn model_block_registers_and_is_usable_as_fs() {
        let mut e = Experiment::default();
        let ini = parse_ini(
            "[model.cfg_lazy]\npublication = phase_end\nacquisition = lifetime_snapshot\n\
             [workload]\nfs = cfg_lazy\n",
        )
        .unwrap();
        e.apply_ini(&ini).unwrap();
        assert_eq!(e.fs.name(), "cfg_lazy");
        assert!(!e.fs.is_builtin());
        // The derived formal model has the session MSC shape.
        assert_eq!(
            e.fs.model().mscs,
            crate::model::SyncPolicy::session().derive_model("x").mscs
        );
        // A broken block is a config error, not a panic.
        let bad = parse_ini("[model.cfg_bad]\npublication = sometimes\n").unwrap();
        assert!(Experiment::default().apply_ini(&bad).is_err());
    }

    #[test]
    fn faults_section_and_run_config() {
        let mut e = Experiment::default();
        assert!(e.faults.is_empty());
        let ini = parse_ini(
            "[faults]\nplan = kill shard 0 at 2ms; restart shard 0 at 4ms\n",
        )
        .unwrap();
        e.apply_ini(&ini).unwrap();
        assert_eq!(e.faults.len(), 2);
        let cfg = e.run_config();
        assert_eq!(cfg.shards, e.shards);
        assert_eq!(cfg.engine_threads, e.engine_threads);
        assert_eq!(cfg.faults, e.faults);
        // Default RunConfig reproduces the historical defaults.
        let d = RunConfig::default();
        assert_eq!((d.shards, d.lazy, d.phantom, d.engine_threads), (1, false, true, 1));
        assert!(d.faults.is_empty() && d.layers.is_none());
    }

    #[test]
    fn run_args_share_validation_text_with_ini() {
        let spec = RunArgs::add_to_spec(ArgSpec::new("t", "t"));
        let argv = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        // Not given → None everywhere.
        let none = RunArgs::from_parsed(&spec.parse(&argv(&[])).unwrap()).unwrap();
        assert!(none.shards.is_none() && none.files.is_none());
        assert!(none.engine_threads.is_none() && none.faults.is_none());
        // Given → parsed, validated, and applied over the experiment.
        let some = RunArgs::from_parsed(
            &spec
                .parse(&argv(&[
                    "--shards=4",
                    "--engine-threads=2",
                    "--faults",
                    "kill shard 1 at 1ms; restart shard 1 at 2ms",
                ]))
                .unwrap(),
        )
        .unwrap();
        let mut e = Experiment::default();
        some.apply_to(&mut e);
        assert_eq!((e.shards, e.engine_threads, e.files), (4, 2, 1));
        assert_eq!(e.faults.len(), 2);
        // THE drift fix: the CLI zero and the INI zero now report the
        // identical canonical message.
        let cli_err = RunArgs::from_parsed(&spec.parse(&argv(&["--engine-threads=0"])).unwrap())
            .unwrap_err();
        let ini_err = Experiment::default()
            .apply_ini(&parse_ini("[cluster]\nengine_threads=0\n").unwrap())
            .unwrap_err();
        assert_eq!(cli_err, ini_err);
        assert_eq!(cli_err, "engine_threads must be >= 1");
        // A malformed fault plan is a flag error, not a panic.
        assert!(
            RunArgs::from_parsed(&spec.parse(&argv(&["--faults", "explode node 3"])).unwrap())
                .is_err()
        );
    }

    #[test]
    fn replication_section_and_flag_overlay() {
        use crate::sim::Ns;
        let mut e = Experiment::default();
        assert!(e.replication.is_none());
        let ini = parse_ini("[replication]\nreplicas = 3\npreset = far\nrtt = 250us\n").unwrap();
        e.apply_ini(&ini).unwrap();
        let p = e.replication.clone().unwrap();
        assert_eq!(p.replicas, 3);
        assert_eq!(p.rtt, Ns::from_micros(250), "explicit rtt overrides the preset");
        assert_eq!(p.tier_step, ReplicaParams::far().tier_step);
        // run_config forwards the plane to the drivers.
        assert_eq!(e.run_config().replication, Some(p));
        // The CLI flag enables the plane with the near preset, or
        // overrides a config-chosen topology's count.
        let spec = RunArgs::add_to_spec(ArgSpec::new("t", "t"));
        let argv: Vec<String> = vec!["--replicas=2".into()];
        let args = RunArgs::from_parsed(&spec.parse(&argv).unwrap()).unwrap();
        let mut fresh = Experiment::default();
        args.apply_to(&mut fresh);
        assert_eq!(fresh.replication, Some(ReplicaParams { replicas: 2, ..ReplicaParams::near() }));
        args.apply_to(&mut e);
        assert_eq!(e.replication.as_ref().unwrap().replicas, 2);
        assert_eq!(e.replication.as_ref().unwrap().rtt, Ns::from_micros(250));
        // `--write-ack` overrides the model axis for the run; the flag
        // shares WriteAck::parse with the [model.*] key, so the bad-
        // value error text cannot drift.
        assert!(fresh.write_ack.is_none());
        let argv: Vec<String> = vec!["--write-ack=sync".into()];
        let args = RunArgs::from_parsed(&spec.parse(&argv).unwrap()).unwrap();
        args.apply_to(&mut fresh);
        assert_eq!(fresh.write_ack, Some(WriteAck::Sync));
        assert_eq!(fresh.run_config().write_ack, Some(WriteAck::Sync));
        let argv: Vec<String> = vec!["--write-ack=quorum".into()];
        assert!(RunArgs::from_parsed(&spec.parse(&argv).unwrap())
            .unwrap_err()
            .contains("write_ack"));
        // Degenerate values are config errors.
        assert!(Experiment::default()
            .apply_ini(&parse_ini("[replication]\nreplicas = 0\n").unwrap())
            .is_err());
        assert!(Experiment::default()
            .apply_ini(&parse_ini("[replication]\npreset = everywhere\n").unwrap())
            .is_err());
        assert!(Experiment::default()
            .apply_ini(&parse_ini("[replication]\nquorum = 2\n").unwrap())
            .is_err());
    }

    #[test]
    fn testbed_presets() {
        assert!(Testbed::parse("CATALYST").is_ok());
        assert!(Testbed::parse("floppy").is_err());
        let c = Testbed::Pmem.cluster(2, 1);
        assert_eq!(c.nodes(), 2);
    }
}
