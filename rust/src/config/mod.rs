//! Experiment configuration: testbed presets (device models), cluster
//! shape, and workload parameters, loadable from an INI-like file with
//! CLI overrides. serde/toml are unavailable offline, so the format is
//! deliberately simple:
//!
//! ```ini
//! # experiment.cfg
//! [cluster]
//! nodes = 16
//! ppn = 12
//! testbed = catalyst   # catalyst | expanse | hdd | pmem
//! engine_threads = 4   # windowed parallel event loop; results identical to 1
//!
//! [workload]
//! config = CC-R
//! fs = session
//! size = 8K
//! m = 10
//! seed = 7
//!
//! # A consistency model defined as DATA (no Rust change): registered
//! # into the model registry, runnable via `fs = lazy` and through the
//! # bench matrix (`pscnf bench --config ... --models lazy`).
//! [model.lazy]
//! publication = phase_end
//! acquisition = lifetime_snapshot
//! ```

use crate::fs::FsKind;
use crate::sim::{Cluster, NetParams, ServerParams, SsdParams, UpfsParams};
use crate::util::units::parse_bytes;
use crate::workload::Config as TableConfig;
use std::collections::BTreeMap;

/// Parsed INI-ish file: section -> key -> value.
pub type Ini = BTreeMap<String, BTreeMap<String, String>>;

/// Parse the config text. Unknown sections/keys are preserved (callers
/// validate what they consume); syntax errors are reported with lines.
pub fn parse_ini(text: &str) -> Result<Ini, String> {
    let mut out: Ini = BTreeMap::new();
    let mut section = String::from("global");
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(stripped) = line.strip_prefix('[') {
            let name = stripped
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
            section = name.trim().to_string();
            out.entry(section.clone()).or_default();
        } else if let Some((k, v)) = line.split_once('=') {
            out.entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v.trim().to_string());
        } else {
            return Err(format!("line {}: expected `key = value`", lineno + 1));
        }
    }
    Ok(out)
}

/// Device-model preset (the paper's testbeds + ablation devices).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Testbed {
    Catalyst,
    Expanse,
    Hdd,
    Pmem,
}

impl Testbed {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "catalyst" => Ok(Testbed::Catalyst),
            "expanse" => Ok(Testbed::Expanse),
            "hdd" => Ok(Testbed::Hdd),
            "pmem" => Ok(Testbed::Pmem),
            other => Err(format!("unknown testbed `{other}`")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Testbed::Catalyst => "catalyst",
            Testbed::Expanse => "expanse",
            Testbed::Hdd => "hdd",
            Testbed::Pmem => "pmem",
        }
    }

    pub fn ssd(&self) -> SsdParams {
        match self {
            Testbed::Catalyst => SsdParams::catalyst(),
            Testbed::Expanse => SsdParams::expanse(),
            Testbed::Hdd => SsdParams::hdd(),
            Testbed::Pmem => SsdParams::pmem(),
        }
    }

    /// Build the simulated cluster for `nodes` nodes.
    pub fn cluster(&self, nodes: usize, seed: u64) -> Cluster {
        self.cluster_sharded(nodes, seed, 1)
    }

    /// Cluster whose metadata plane has `shards` shards (1 = the
    /// paper's single global server).
    pub fn cluster_sharded(&self, nodes: usize, seed: u64, shards: usize) -> Cluster {
        Cluster::new(
            nodes,
            self.ssd(),
            NetParams::ib_qdr(),
            ServerParams::catalyst_sharded(shards),
            UpfsParams::catalyst_lustre(),
            seed,
        )
    }
}

/// Full experiment spec assembled from file + CLI.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub testbed: Testbed,
    pub nodes: usize,
    pub ppn: usize,
    /// Metadata-plane shards (`[cluster] shards`); 1 = the paper's
    /// single global server.
    pub shards: usize,
    pub fs: FsKind,
    pub workload: TableConfig,
    pub access_size: u64,
    pub accesses_per_proc: usize,
    /// Shared files the dataset is striped over (`[workload] files`).
    pub files: usize,
    /// Windowed parallel event-loop width (`[cluster] engine_threads`);
    /// 1 = the serial loop. Any value yields byte-identical results —
    /// the knob only trades wall time, so it lives next to the cluster
    /// shape rather than the workload.
    pub engine_threads: usize,
    pub seed: u64,
}

impl Default for Experiment {
    fn default() -> Self {
        Self {
            testbed: Testbed::Catalyst,
            nodes: 4,
            ppn: 12,
            shards: 1,
            fs: FsKind::SESSION,
            workload: TableConfig::CcR,
            access_size: 8 << 10,
            accesses_per_proc: 10,
            files: 1,
            engine_threads: 1,
            seed: 7,
        }
    }
}

impl Experiment {
    /// Overlay values from an INI file. `[model.<name>]` sections are
    /// registered into the model registry FIRST, so `[workload] fs`
    /// (and every later CLI flag) can name a model that exists only in
    /// this file.
    pub fn apply_ini(&mut self, ini: &Ini) -> Result<(), String> {
        FsKind::register_from_ini(ini)?;
        if let Some(cluster) = ini.get("cluster") {
            if let Some(v) = cluster.get("nodes") {
                self.nodes = v.parse().map_err(|e| format!("cluster.nodes: {e}"))?;
            }
            if let Some(v) = cluster.get("ppn") {
                self.ppn = v.parse().map_err(|e| format!("cluster.ppn: {e}"))?;
            }
            if let Some(v) = cluster.get("testbed") {
                self.testbed = Testbed::parse(v)?;
            }
            if let Some(v) = cluster.get("shards") {
                self.shards = v.parse().map_err(|e| format!("cluster.shards: {e}"))?;
                if self.shards == 0 {
                    return Err("cluster.shards must be >= 1".to_string());
                }
            }
            if let Some(v) = cluster.get("engine_threads") {
                self.engine_threads = v
                    .parse()
                    .map_err(|e| format!("cluster.engine_threads: {e}"))?;
                if self.engine_threads == 0 {
                    return Err("cluster.engine_threads must be >= 1".to_string());
                }
            }
        }
        if let Some(w) = ini.get("workload") {
            if let Some(v) = w.get("config") {
                self.workload = TableConfig::parse(v)?;
            }
            if let Some(v) = w.get("fs") {
                self.fs = FsKind::parse(v)?;
            }
            if let Some(v) = w.get("size") {
                self.access_size = parse_bytes(v)?;
            }
            if let Some(v) = w.get("m") {
                self.accesses_per_proc = v.parse().map_err(|e| format!("workload.m: {e}"))?;
            }
            if let Some(v) = w.get("seed") {
                self.seed = v.parse().map_err(|e| format!("workload.seed: {e}"))?;
            }
            if let Some(v) = w.get("files") {
                self.files = v.parse().map_err(|e| format!("workload.files: {e}"))?;
                if self.files == 0 {
                    return Err("workload.files must be >= 1".to_string());
                }
            }
        }
        Ok(())
    }

    pub fn params(&self) -> crate::workload::WorkloadParams {
        self.workload
            .params(
                self.nodes,
                self.ppn,
                self.access_size,
                self.accesses_per_proc,
                self.seed,
            )
            .with_files(self.files)
    }

    pub fn cluster(&self) -> Cluster {
        self.testbed
            .cluster_sharded(self.nodes, self.seed ^ 0xC1A5, self.shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ini_parses_sections_comments() {
        let ini = parse_ini(
            "# top comment\n[cluster]\nnodes = 8 # inline\nppn=4\n\n[workload]\nfs = commit\n",
        )
        .unwrap();
        assert_eq!(ini["cluster"]["nodes"], "8");
        assert_eq!(ini["cluster"]["ppn"], "4");
        assert_eq!(ini["workload"]["fs"], "commit");
    }

    #[test]
    fn ini_rejects_bad_lines() {
        assert!(parse_ini("[cluster\n").is_err());
        assert!(parse_ini("justaword\n").is_err());
    }

    #[test]
    fn experiment_overlay() {
        let mut e = Experiment::default();
        let ini = parse_ini(
            "[cluster]\nnodes=16\ntestbed=expanse\n[workload]\nconfig=CS-R\nfs=commit\nsize=8M\nm=5\n",
        )
        .unwrap();
        e.apply_ini(&ini).unwrap();
        assert_eq!(e.nodes, 16);
        assert_eq!(e.testbed, Testbed::Expanse);
        assert_eq!(e.fs, FsKind::COMMIT);
        assert_eq!(e.access_size, 8 << 20);
        assert_eq!(e.accesses_per_proc, 5);
        let p = e.params();
        assert_eq!(p.n_w, 8);
        assert_eq!(p.n_r, 8);
    }

    #[test]
    fn shards_and_files_overlay() {
        let mut e = Experiment::default();
        assert_eq!(e.shards, 1);
        assert_eq!(e.files, 1);
        assert_eq!(e.engine_threads, 1);
        let ini =
            parse_ini("[cluster]\nshards=8\nengine_threads=4\n[workload]\nfiles=16\n").unwrap();
        e.apply_ini(&ini).unwrap();
        assert_eq!(e.shards, 8);
        assert_eq!(e.files, 16);
        assert_eq!(e.engine_threads, 4);
        assert_eq!(e.params().files, 16);
        assert_eq!(e.cluster().server.shard_count(), 8);
        // Zero is rejected for all three.
        assert!(Experiment::default()
            .apply_ini(&parse_ini("[cluster]\nshards=0\n").unwrap())
            .is_err());
        assert!(Experiment::default()
            .apply_ini(&parse_ini("[workload]\nfiles=0\n").unwrap())
            .is_err());
        assert!(Experiment::default()
            .apply_ini(&parse_ini("[cluster]\nengine_threads=0\n").unwrap())
            .is_err());
    }

    #[test]
    fn model_block_registers_and_is_usable_as_fs() {
        let mut e = Experiment::default();
        let ini = parse_ini(
            "[model.cfg_lazy]\npublication = phase_end\nacquisition = lifetime_snapshot\n\
             [workload]\nfs = cfg_lazy\n",
        )
        .unwrap();
        e.apply_ini(&ini).unwrap();
        assert_eq!(e.fs.name(), "cfg_lazy");
        assert!(!e.fs.is_builtin());
        // The derived formal model has the session MSC shape.
        assert_eq!(
            e.fs.model().mscs,
            crate::model::SyncPolicy::session().derive_model("x").mscs
        );
        // A broken block is a config error, not a panic.
        let bad = parse_ini("[model.cfg_bad]\npublication = sometimes\n").unwrap();
        assert!(Experiment::default().apply_ini(&bad).is_err());
    }

    #[test]
    fn testbed_presets() {
        assert!(Testbed::parse("CATALYST").is_ok());
        assert!(Testbed::parse("floppy").is_err());
        let c = Testbed::Pmem.cluster(2, 1);
        assert_eq!(c.nodes(), 2);
    }
}
