//! Small, fast, dependency-free PRNGs.
//!
//! The environment has no `rand` crate, so we carry our own:
//! [`SplitMix64`] for seeding and [`Xoshiro256pp`] (xoshiro256++) as the
//! general-purpose generator. Both are well-studied public-domain
//! algorithms (Blackman & Vigna). Determinism matters more than
//! cryptographic quality here: every simulation, workload shuffle, and
//! property test is reproducible from a single `u64` seed.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

/// Convenient alias used throughout the crate.
pub type Rng = Xoshiro256pp;

impl Xoshiro256pp {
    /// Seed from a single u64 via SplitMix64 (the reference seeding recipe).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        // All-zero state is invalid; SplitMix64 never yields it for four
        // consecutive outputs, but guard anyway.
        if s == [0, 0, 0, 0] {
            return Self { s: [1, 2, 3, 4] };
        }
        Self { s }
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range_u64(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range_u64 bound must be > 0");
        // Rejection-free fast path is fine for our (non-crypto) uses: use
        // 128-bit multiply to map uniformly with negligible bias, then fix
        // bias with rejection.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`. Panics if `lo >= hi`.
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "gen_range: empty range {lo}..{hi}");
        lo + self.gen_range_u64((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// True with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: first k positions are a uniform sample.
        for i in 0..k {
            let j = self.gen_range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fork a statistically-independent child generator (for per-rank RNGs).
    pub fn fork(&mut self) -> Self {
        Self::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let v = r.gen_range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::seed_from_u64(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(0, 8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from_u64(11);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::seed_from_u64(13);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.next_normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn fork_is_independent_stream() {
        let mut parent = Rng::seed_from_u64(21);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
