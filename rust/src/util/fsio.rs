//! Filesystem helpers shared by every artifact-writing path (bench
//! `--out`, trace recording, result persistence). One definition of
//! "create the missing parent directories first" so a fresh CI
//! workspace never fails a write with a bare io error.

use std::path::Path;

/// Create `path`'s parent directory (and all ancestors) if missing.
/// A bare filename (no parent, or an empty one) is a no-op: the
/// current directory always exists.
pub fn ensure_parent_dir(path: &Path) -> Result<(), String> {
    match path.parent() {
        Some(parent) if !parent.as_os_str().is_empty() => std::fs::create_dir_all(parent)
            .map_err(|e| format!("create {}: {e}", parent.display())),
        _ => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_missing_parents_and_tolerates_existing_ones() {
        let base = std::env::temp_dir().join(format!(
            "pscnf-fsio-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&base);
        let target = base.join("a/b/c/out.json");
        ensure_parent_dir(&target).unwrap();
        assert!(target.parent().unwrap().is_dir());
        // Idempotent: already-existing parents are fine.
        ensure_parent_dir(&target).unwrap();
        std::fs::write(&target, b"{}").unwrap();
        assert!(target.exists());
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn bare_filename_is_a_noop() {
        ensure_parent_dir(Path::new("just-a-name.json")).unwrap();
        ensure_parent_dir(Path::new("")).unwrap();
    }
}
