//! Minimal JSON value, emitter, and parser (serde is not available
//! offline). The emit side produces machine-readable benchmark /
//! experiment outputs (`target/results/*.json`); the parse side reads
//! them back, which is what `pscnf bench --compare` uses to diff a run
//! against a stored baseline.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `Object` uses a BTreeMap for deterministic key order —
/// results files diff cleanly across runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object (programming
    /// error, not data error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(map) => {
                map.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object entries, if `self` is an object.
    pub fn entries(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(map) => Some(map),
            _ => None,
        }
    }

    /// Parse a JSON document — the inverse of [`Json::dump`] /
    /// [`Json::pretty`]. All numbers become [`Json::Num`] (f64), matching
    /// the value model; `\uXXXX` escapes (including surrogate pairs) are
    /// decoded. Errors carry the byte offset of the failure.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            s: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.s.len() {
            return Err(p.fail("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    // JSON has no Inf/NaN; encode as null like most emitters.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..width * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Nesting depth beyond which [`Json::parse`] refuses to recurse (our
/// results files are a few levels deep; this only guards the stack
/// against pathological input).
const MAX_DEPTH: usize = 128;

/// Recursive-descent parser over the UTF-8 bytes of the input.
struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn fail(&self, msg: &str) -> String {
        format!("json: {msg} at byte {}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.fail(&format!("expected `{}`", c as char)))
        }
    }

    fn literal(&mut self, lit: &'static str, v: Json) -> Result<Json, String> {
        if self.s[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.fail("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        if self.depth >= MAX_DEPTH {
            return Err(self.fail("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.fail("unexpected character")),
            None => Err(self.fail("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        self.depth += 1;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.fail("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        self.depth += 1;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.fail("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = match self.bump() {
                Some(c) => c,
                None => return Err(self.fail("unterminated string")),
            };
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = match self.bump() {
                        Some(e) => e,
                        None => return Err(self.fail("unterminated escape")),
                    };
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                    return Err(self.fail("lone high surrogate"));
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.fail("invalid low surrogate"));
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            match char::from_u32(cp) {
                                Some(ch) => out.push(ch),
                                None => return Err(self.fail("invalid \\u escape")),
                            }
                        }
                        _ => return Err(self.fail("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.fail("raw control character in string")),
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // Multi-byte UTF-8. The input came from a &str, so
                    // from this (leading-byte) position the suffix is
                    // valid UTF-8; copy the whole char through.
                    let start = self.pos - 1;
                    let bytes: &'a [u8] = self.s;
                    let rest = std::str::from_utf8(&bytes[start..])
                        .map_err(|_| self.fail("invalid utf-8"))?;
                    let ch = rest.chars().next().expect("non-empty suffix");
                    out.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = self
                .bump()
                .and_then(|c| (c as char).to_digit(16))
                .ok_or_else(|| self.fail("bad \\u escape (want 4 hex digits)"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.pos]).expect("ascii number");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.fail("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_dump() {
        assert_eq!(Json::Null.dump(), "null");
        assert_eq!(Json::from(true).dump(), "true");
        assert_eq!(Json::from(42u64).dump(), "42");
        assert_eq!(Json::from(1.5).dump(), "1.5");
        assert_eq!(Json::from("hi").dump(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").dump(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn object_is_sorted_and_nested() {
        let mut o = Json::obj();
        o.set("b", 2u64).set("a", 1u64);
        o.set("list", vec![1u64, 2, 3]);
        assert_eq!(o.dump(), "{\"a\":1,\"b\":2,\"list\":[1,2,3]}");
    }

    #[test]
    fn pretty_roundtrips_structure() {
        let mut o = Json::obj();
        o.set("x", 1u64);
        let p = o.pretty();
        assert!(p.contains("\n  \"x\": 1\n"));
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Json::from(f64::NAN).dump(), "null");
        assert_eq!(Json::from(f64::INFINITY).dump(), "null");
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
    }

    #[test]
    fn parse_string_escapes() {
        assert_eq!(
            Json::parse(r#""a\"b\\c\nd\u0041""#).unwrap(),
            Json::Str("a\"b\\c\ndA".into())
        );
        // Surrogate pair (U+1F600) and raw multi-byte UTF-8.
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("\u{1F600}".into())
        );
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"unterminated",
            "{\"a\":1} extra", "\"\\q\"", "\"\\ud83d\"", "nul",
        ] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn dump_parse_round_trips() {
        let mut o = Json::obj();
        o.set("b", 2u64)
            .set("a", 1.25)
            .set("s", "quote\"back\\slash\nnewline")
            .set("flag", true)
            .set("nothing", Json::Null)
            .set("list", vec![1u64, 2, 3]);
        let mut inner = Json::obj();
        inner.set("deep", vec!["x", "y"]);
        o.set("obj", inner);
        for text in [o.dump(), o.pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), o, "round trip of {text}");
        }
    }

    #[test]
    fn parse_depth_guard() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&deep).is_err());
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&ok).is_ok());
    }
}
