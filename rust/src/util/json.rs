//! Minimal JSON value + emitter (serde is not available offline).
//! Used for machine-readable benchmark/experiment outputs so figures can
//! be regenerated/plotted from `target/results/*.json`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `Object` uses a BTreeMap for deterministic key order —
/// results files diff cleanly across runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object (programming
    /// error, not data error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(map) => {
                map.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    // JSON has no Inf/NaN; encode as null like most emitters.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..width * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_dump() {
        assert_eq!(Json::Null.dump(), "null");
        assert_eq!(Json::from(true).dump(), "true");
        assert_eq!(Json::from(42u64).dump(), "42");
        assert_eq!(Json::from(1.5).dump(), "1.5");
        assert_eq!(Json::from("hi").dump(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").dump(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn object_is_sorted_and_nested() {
        let mut o = Json::obj();
        o.set("b", 2u64).set("a", 1u64);
        o.set("list", vec![1u64, 2, 3]);
        assert_eq!(o.dump(), "{\"a\":1,\"b\":2,\"list\":[1,2,3]}");
    }

    #[test]
    fn pretty_roundtrips_structure() {
        let mut o = Json::obj();
        o.set("x", 1u64);
        let p = o.pretty();
        assert!(p.contains("\n  \"x\": 1\n"));
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Json::from(f64::NAN).dump(), "null");
        assert_eq!(Json::from(f64::INFINITY).dump(), "null");
    }
}
