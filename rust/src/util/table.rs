//! Plain-text table rendering for CLI reports and bench output — every
//! reproduced paper table/figure prints through this so rows line up and
//! are grep-able in `bench_output.txt`.

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                // Right-align numeric-looking cells, left-align the rest.
                let numeric = cell
                    .chars()
                    .next()
                    .map(|c| c.is_ascii_digit() || c == '-' || c == '+')
                    .unwrap_or(false)
                    && cell.chars().any(|c| c.is_ascii_digit());
                if numeric {
                    line.push_str(&format!("{:>width$}", cell, width = widths[i]));
                } else {
                    line.push_str(&format!("{:<width$}", cell, width = widths[i]));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (no quoting needed for our numeric/identifier cells;
    /// commas inside cells are replaced by `;`).
    pub fn to_csv(&self) -> String {
        let clean = |s: &str| s.replace(',', ";");
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|h| clean(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter()
                    .map(|c| clean(c))
                    .collect::<Vec<_>>()
                    .join(","),
            );
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "bw"]);
        t.row(vec!["commit", "123.45"]);
        t.row(vec!["session", "5.0"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].contains("commit"));
        // numeric right-aligned to same column end
        assert_eq!(lines[2].len(), lines[0].len().max(lines[2].len()));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "1"]);
        assert_eq!(t.to_csv(), "a,b\nx;y,1\n");
    }
}
