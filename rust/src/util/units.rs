//! Byte-size and duration parsing/formatting used by configs, the CLI,
//! and report rendering. `1GiB`-style binary units are the default for
//! storage sizes (the paper's "8KB"/"8MB" access sizes are binary).

/// Parse a human byte size: `8K`, `8KB`, `8KiB`, `1m`, `2GiB`, `117`, `4096B`.
/// Units are binary (K = 1024) as is conventional for I/O access sizes.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty byte-size string".into());
    }
    let lower = s.to_ascii_lowercase();
    let split = lower
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(lower.len());
    let (num, unit) = lower.split_at(split);
    if num.is_empty() {
        return Err(format!("byte size `{s}` has no numeric part"));
    }
    let value: f64 = num
        .parse()
        .map_err(|e| format!("bad byte size `{s}`: {e}"))?;
    let mult: u64 = match unit.trim() {
        "" | "b" => 1,
        "k" | "kb" | "kib" => 1 << 10,
        "m" | "mb" | "mib" => 1 << 20,
        "g" | "gb" | "gib" => 1 << 30,
        "t" | "tb" | "tib" => 1 << 40,
        other => return Err(format!("unknown byte unit `{other}` in `{s}`")),
    };
    let bytes = value * mult as f64;
    if bytes < 0.0 || bytes > u64::MAX as f64 {
        return Err(format!("byte size `{s}` out of range"));
    }
    Ok(bytes.round() as u64)
}

/// Format bytes with a binary-unit suffix, trimmed to 2 decimals.
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [(&str, u64); 5] = [
        ("TiB", 1 << 40),
        ("GiB", 1 << 30),
        ("MiB", 1 << 20),
        ("KiB", 1 << 10),
        ("B", 1),
    ];
    for (name, mult) in UNITS {
        if bytes >= mult {
            let v = bytes as f64 / mult as f64;
            return if (v - v.round()).abs() < 1e-9 {
                format!("{}{}", v.round() as u64, name)
            } else {
                format!("{v:.2}{name}")
            };
        }
    }
    "0B".to_string()
}

/// Format a bandwidth (bytes/sec) as `X.XX GiB/s` style.
pub fn fmt_bandwidth(bytes_per_sec: f64) -> String {
    const UNITS: [(&str, f64); 4] = [
        ("GiB/s", (1u64 << 30) as f64),
        ("MiB/s", (1u64 << 20) as f64),
        ("KiB/s", (1u64 << 10) as f64),
        ("B/s", 1.0),
    ];
    for (name, mult) in UNITS {
        if bytes_per_sec >= mult {
            return format!("{:.2}{}", bytes_per_sec / mult, name);
        }
    }
    format!("{bytes_per_sec:.2}B/s")
}

/// Parse durations like `5s`, `120ms`, `2.5us`, `3m`, `100ns`.
pub fn parse_duration_secs(s: &str) -> Result<f64, String> {
    let s = s.trim().to_ascii_lowercase();
    if s.is_empty() {
        return Err("empty duration string".into());
    }
    let split = s
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let value: f64 = num
        .parse()
        .map_err(|e| format!("bad duration `{s}`: {e}"))?;
    let mult = match unit.trim() {
        "" | "s" | "sec" | "secs" => 1.0,
        "ms" => 1e-3,
        "us" | "µs" => 1e-6,
        "ns" => 1e-9,
        "m" | "min" => 60.0,
        "h" => 3600.0,
        other => return Err(format!("unknown duration unit `{other}` in `{s}`")),
    };
    Ok(value * mult)
}

/// Format a duration in seconds with an adaptive unit.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_plain_and_units() {
        assert_eq!(parse_bytes("117").unwrap(), 117);
        assert_eq!(parse_bytes("4096B").unwrap(), 4096);
        assert_eq!(parse_bytes("8K").unwrap(), 8192);
        assert_eq!(parse_bytes("8KB").unwrap(), 8192);
        assert_eq!(parse_bytes("8KiB").unwrap(), 8192);
        assert_eq!(parse_bytes("8M").unwrap(), 8 << 20);
        assert_eq!(parse_bytes("1.5k").unwrap(), 1536);
        assert_eq!(parse_bytes(" 2GiB ").unwrap(), 2 << 30);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("KB").is_err());
        assert!(parse_bytes("12xyz").is_err());
        assert!(parse_bytes("-5K").is_err());
    }

    #[test]
    fn roundtrip_formatting() {
        assert_eq!(fmt_bytes(0), "0B");
        assert_eq!(fmt_bytes(8192), "8KiB");
        assert_eq!(fmt_bytes(8 << 20), "8MiB");
        assert_eq!(fmt_bytes(1536), "1.50KiB");
    }

    #[test]
    fn bandwidth_formatting() {
        assert_eq!(fmt_bandwidth((1u64 << 30) as f64), "1.00GiB/s");
        assert_eq!(fmt_bandwidth(512.0 * 1024.0 * 1024.0), "512.00MiB/s");
    }

    #[test]
    fn durations() {
        assert_eq!(parse_duration_secs("5s").unwrap(), 5.0);
        assert!((parse_duration_secs("120ms").unwrap() - 0.12).abs() < 1e-12);
        assert!((parse_duration_secs("2.5us").unwrap() - 2.5e-6).abs() < 1e-15);
        assert_eq!(fmt_duration(0.002), "2.000ms");
        assert_eq!(fmt_duration(3.5), "3.500s");
    }
}
