//! Self-contained stderr logger (the `log` facade crate is not
//! available offline). Level from `PSCNF_LOG` (`error..trace`), plain
//! stderr lines. Installed once by binaries/benches via `init()`; the
//! [`log_warn!`](crate::log_warn) family of macros is usable anywhere
//! in the crate without `init()` (messages below the level are dropped).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Max enabled level; default Warn.
static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Install the logger; idempotent. Level from `PSCNF_LOG` env var
/// (`error|warn|info|debug|trace`), default `warn`.
pub fn init() {
    let level = match std::env::var("PSCNF_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    };
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record; prefer the `log_*!` macros.
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {target}: {args}", level.tag());
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        crate::log_warn!("logger smoke");
    }

    #[test]
    fn level_ordering_gates() {
        init();
        // Default level is warn unless PSCNF_LOG overrides; error is
        // always at least as enabled as trace.
        assert!(enabled(Level::Error) || !enabled(Level::Warn));
        assert!(!enabled(Level::Trace) || enabled(Level::Debug));
    }
}
