//! Minimal `log`-facade backend: level from `PSCNF_LOG` (error..trace),
//! plain stderr lines. Installed once by binaries/benches via `init()`.

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{lvl}] {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger; idempotent. Level from `PSCNF_LOG` env var
/// (`error|warn|info|debug|trace`), default `warn`.
pub fn init() {
    let level = match std::env::var("PSCNF_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("info") => LevelFilter::Info,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Warn,
    };
    // set_logger errors if called twice; that's fine.
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::warn!("logger smoke");
    }
}
