//! Minimal error plumbing (anyhow is not available offline): a string
//! error type, a `Context` extension for `Result`/`Option`, and a
//! [`bail!`](crate::bail) macro. Domain enums implement
//! `std::error::Error` by hand; this module covers the glue-code paths
//! (runtime loading, CLI) where anyhow would otherwise be used.

use std::fmt;

/// A boxed-string error: cheap to construct, Display-first.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error(String);

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error(s.to_string())
    }
}

/// Crate-default result type for glue code.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// anyhow-style context chaining: prepends a message to the error.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error(msg.to_string()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error(f()))
    }
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<u32> = None;
        let e = o.with_context(|| "missing".to_string()).unwrap_err();
        assert_eq!(e.to_string(), "missing");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn bail_formats() {
        fn f(n: usize) -> Result<()> {
            if n > 2 {
                bail!("too big: {n}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(f(9).unwrap_err().to_string(), "too big: 9");
    }
}
