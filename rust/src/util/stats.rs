//! Summary statistics for benchmark reporting: mean, stddev, percentiles,
//! min/max, plus a fixed-bucket latency histogram. All figures in the
//! paper report averages over >=10 repeats; `Summary` is what every bench
//! row prints.

use std::cell::OnceCell;

/// Single-pass-friendly collection of samples with summary accessors.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    values: Vec<f64>,
    /// Lazily built sorted copy, shared by every percentile/min/max
    /// call and invalidated on push — one sort per sample set instead
    /// of one per call (p50 + p95 per bench row across ~280 scenario
    /// cells used to re-sort twice per record).
    sorted: OnceCell<Vec<f64>>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        debug_assert!(v.is_finite(), "non-finite sample {v}");
        self.values.push(v);
        self.sorted.take(); // invalidate the cached order
    }

    /// The cached ascending copy of the values (built on first use).
    fn sorted(&self) -> &[f64] {
        self.sorted.get_or_init(|| {
            let mut s = self.values.clone();
            s.sort_by(f64::total_cmp);
            s
        })
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Sample standard deviation (n-1 denominator); 0 for n < 2.
    pub fn stddev(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let ss: f64 = self.values.iter().map(|v| (v - m) * (v - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    /// Smallest sample; 0.0 when empty (matching `mean`'s empty-case
    /// convention — `±INFINITY` previously leaked non-finite values
    /// into serialized bench records and poisoned `--compare`).
    pub fn min(&self) -> f64 {
        self.sorted().first().copied().unwrap_or(0.0)
    }

    /// Largest sample; 0.0 when empty (see [`Samples::min`]).
    pub fn max(&self) -> f64 {
        self.sorted().last().copied().unwrap_or(0.0)
    }

    /// Linear-interpolated percentile, `q` in [0,100]. Uses the cached
    /// sorted copy — repeated calls cost one sort total.
    pub fn percentile(&self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q), "percentile q={q}");
        let sorted = self.sorted();
        if sorted.is_empty() {
            return 0.0;
        }
        let pos = q / 100.0 * (sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = pos - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn summary(&self) -> Summary {
        Summary {
            n: self.len(),
            mean: self.mean(),
            stddev: self.stddev(),
            min: self.min(),
            max: self.max(),
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
        }
    }
}

/// Immutable summary of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Relative stddev (coefficient of variation); 0 when mean is 0.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean
        }
    }
}

/// Log2-bucketed histogram for latencies/sizes spanning orders of magnitude.
#[derive(Debug, Clone)]
pub struct Log2Histogram {
    /// counts[i] counts values v with 2^i <= v < 2^(i+1); counts[0] also
    /// holds v < 1.
    counts: Vec<u64>,
    total: u64,
}

impl Default for Log2Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Log2Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; 64],
            total: 0,
        }
    }

    pub fn record(&mut self, v: f64) {
        let bucket = if v < 1.0 {
            0
        } else {
            (v.log2().floor() as usize).min(63)
        };
        self.counts[bucket] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn bucket_count(&self, i: usize) -> u64 {
        self.counts[i]
    }

    /// Non-empty buckets as (lower_bound, count).
    pub fn nonzero(&self) -> Vec<(f64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (if i == 0 { 0.0 } else { (1u64 << i) as f64 }, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let mut s = Samples::new();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(v);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample stddev of this classic set is ~2.138
        assert!((s.stddev() - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for v in 1..=100 {
            s.push(v as f64);
        }
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-12);
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_is_safe() {
        let s = Samples::new();
        let sum = s.summary();
        assert_eq!(sum.n, 0);
        assert_eq!(sum.mean, 0.0);
        assert_eq!(sum.p99, 0.0);
    }

    #[test]
    fn empty_min_max_are_finite_zero() {
        // Regression: the old fold identities returned ±INFINITY, which
        // leaked non-finite values into BENCH json and broke --compare.
        let s = Samples::new();
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert!(s.min().is_finite() && s.max().is_finite());
    }

    #[test]
    fn sorted_cache_invalidates_on_push() {
        let mut s = Samples::new();
        s.push(5.0);
        assert_eq!(s.percentile(50.0), 5.0); // builds the cache
        assert_eq!(s.max(), 5.0);
        s.push(1.0); // must invalidate
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.percentile(0.0), 1.0);
        // Clones carry consistent state too.
        let mut c = s.clone();
        c.push(9.0);
        assert_eq!(c.max(), 9.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn single_sample() {
        let mut s = Samples::new();
        s.push(3.25);
        let sum = s.summary();
        assert_eq!(sum.n, 1);
        assert_eq!(sum.mean, 3.25);
        assert_eq!(sum.stddev, 0.0);
        assert_eq!(sum.min, 3.25);
        assert_eq!(sum.max, 3.25);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Log2Histogram::new();
        h.record(0.5);
        h.record(1.0);
        h.record(3.0);
        h.record(1024.0);
        assert_eq!(h.total(), 4);
        assert_eq!(h.bucket_count(0), 2); // 0.5 and 1.0
        assert_eq!(h.bucket_count(1), 1); // 3.0
        assert_eq!(h.bucket_count(10), 1); // 1024
        assert_eq!(h.nonzero().len(), 3);
    }
}
