//! A fast non-cryptographic hasher for integer keys (FxHash-style
//! multiply-fold). The global server hashes a `FileId` per RPC; SipHash
//! (std's default, HashDoS-resistant) is wasted work on internal u64
//! ids — see EXPERIMENTS.md §Perf.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Firefox's FxHash fold constant (64-bit).
const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Default, Clone, Copy)]
pub struct FxHasher {
    hash: u64,
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u8(b);
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// HashMap with the fast hasher — for internal integer-keyed maps only.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_keys_distinct_hashes() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..10_000u64 {
            m.insert(i * 0x9E37_79B9, i as u32);
        }
        assert_eq!(m.len(), 10_000);
        assert_eq!(m[&0], 0);
        assert_eq!(m[&(9_999 * 0x9E37_79B9)], 9_999);
    }

    #[test]
    fn hasher_is_deterministic() {
        let h = |v: u64| {
            let mut hh = FxHasher::default();
            hh.write_u64(v);
            hh.finish()
        };
        assert_eq!(h(42), h(42));
        assert_ne!(h(42), h(43));
    }
}
