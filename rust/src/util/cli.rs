//! Tiny declarative command-line parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! auto-generated `--help`. Subcommand dispatch is handled by the caller
//! (see `main.rs`): the first positional token selects the subcommand and
//! the rest is parsed with that subcommand's `ArgSpec`.

use std::collections::BTreeMap;

/// Declarative option specification.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None => boolean flag; Some(metavar) => takes a value.
    pub value: Option<&'static str>,
    pub default: Option<&'static str>,
}

/// A set of options for one (sub)command.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub command: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positional: Vec<(&'static str, &'static str)>,
}

impl ArgSpec {
    pub fn new(command: &'static str, about: &'static str) -> Self {
        Self {
            command,
            about,
            opts: Vec::new(),
            positional: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            value: None,
            default: None,
        });
        self
    }

    pub fn opt(
        mut self,
        name: &'static str,
        metavar: &'static str,
        default: Option<&'static str>,
        help: &'static str,
    ) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            value: Some(metavar),
            default,
        });
        self
    }

    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.positional.push((name, help));
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  pscnf {}", self.command, self.about, self.command);
        for (p, _) in &self.positional {
            out.push_str(&format!(" <{p}>"));
        }
        if !self.opts.is_empty() {
            out.push_str(" [OPTIONS]");
        }
        out.push('\n');
        if !self.positional.is_empty() {
            out.push_str("\nARGS:\n");
            for (p, h) in &self.positional {
                out.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            out.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let lhs = match o.value {
                    Some(mv) => format!("--{} <{}>", o.name, mv),
                    None => format!("--{}", o.name),
                };
                let def = o
                    .default
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                out.push_str(&format!("  {lhs:<28} {}{def}\n", o.help));
            }
        }
        out
    }

    /// Parse `argv` (not including the program/subcommand name).
    pub fn parse(&self, argv: &[String]) -> Result<ParsedArgs, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: BTreeMap<String, bool> = BTreeMap::new();
        let mut explicit: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        let mut positional: Vec<String> = Vec::new();

        for o in &self.opts {
            if let (Some(_), Some(d)) = (o.value, o.default) {
                values.insert(o.name.to_string(), d.to_string());
            }
            if o.value.is_none() {
                flags.insert(o.name.to_string(), false);
            }
        }

        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                match (spec.value, inline) {
                    (None, None) => {
                        explicit.insert(name.clone());
                        flags.insert(name, true);
                    }
                    (None, Some(_)) => {
                        return Err(format!("option --{name} does not take a value"));
                    }
                    (Some(_), Some(v)) => {
                        explicit.insert(name.clone());
                        values.insert(name, v);
                    }
                    (Some(_), None) => {
                        i += 1;
                        let v = argv
                            .get(i)
                            .ok_or_else(|| format!("option --{name} requires a value"))?;
                        explicit.insert(name.clone());
                        values.insert(name, v.clone());
                    }
                }
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }

        if positional.len() < self.positional.len() {
            return Err(format!(
                "missing required argument <{}>\n\n{}",
                self.positional[positional.len()].0,
                self.usage()
            ));
        }
        Ok(ParsedArgs {
            values,
            flags,
            explicit,
            positional,
        })
    }
}

/// Result of parsing; typed accessors do the string conversions.
#[derive(Debug, Clone)]
pub struct ParsedArgs {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    explicit: std::collections::BTreeSet<String>,
    positional: Vec<String>,
}

impl ParsedArgs {
    pub fn flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }

    /// Was `name` given on the command line (vs. filled from its
    /// default)? Lets callers layer CLI > config-file > built-in.
    pub fn explicit(&self, name: &str) -> bool {
        self.explicit.contains(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> Result<&str, String> {
        self.get(name)
            .ok_or_else(|| format!("missing required option --{name}"))
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.str(name)?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.str(name)?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.str(name)?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    /// Comma-separated list of unsigned integers, e.g. `--nodes 2,4,8`.
    /// An empty string parses to an empty list (callers treat that as
    /// "no filter").
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, String> {
        let raw = self.str(name)?;
        if raw.trim().is_empty() {
            return Ok(Vec::new());
        }
        raw.split(',')
            .map(|x| x.trim().parse().map_err(|e| format!("--{name}: {e}")))
            .collect()
    }

    /// Byte-size option, e.g. `--size 8K`.
    pub fn bytes(&self, name: &str) -> Result<u64, String> {
        super::units::parse_bytes(self.str(name)?).map_err(|e| format!("--{name}: {e}"))
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positional.get(idx).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("run", "run a workload")
            .pos("workload", "workload name")
            .opt("nodes", "N", Some("4"), "number of nodes")
            .opt("size", "BYTES", Some("8K"), "access size")
            .flag("verbose", "chatty output")
    }

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = spec().parse(&args(&["cnw"])).unwrap();
        assert_eq!(p.usize("nodes").unwrap(), 4);
        assert_eq!(p.bytes("size").unwrap(), 8192);
        assert!(!p.flag("verbose"));
        assert_eq!(p.positional(0), Some("cnw"));
        assert!(!p.explicit("nodes"), "default must not count as explicit");
    }

    #[test]
    fn explicit_tracks_cli_provenance() {
        let p = spec()
            .parse(&args(&["cnw", "--nodes", "16", "--size=8M", "--verbose"]))
            .unwrap();
        assert!(p.explicit("nodes"));
        assert!(p.explicit("size"));
        assert!(p.explicit("verbose"));
        assert!(!p.explicit("unknown-name"));
    }

    #[test]
    fn overrides_and_equals_form() {
        let p = spec()
            .parse(&args(&["cnw", "--nodes", "16", "--size=8M", "--verbose"]))
            .unwrap();
        assert_eq!(p.usize("nodes").unwrap(), 16);
        assert_eq!(p.bytes("size").unwrap(), 8 << 20);
        assert!(p.flag("verbose"));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(spec().parse(&args(&["cnw", "--bogus"])).is_err());
    }

    #[test]
    fn missing_positional_errors() {
        let e = spec().parse(&args(&[])).unwrap_err();
        assert!(e.contains("workload"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(spec().parse(&args(&["cnw", "--nodes"])).is_err());
    }

    #[test]
    fn usize_list_parses_and_empty_is_empty() {
        let spec = ArgSpec::new("t", "t").opt("scales", "LIST", Some(""), "node counts");
        let p = spec.parse(&args(&["--scales", "2, 4,8"])).unwrap();
        assert_eq!(p.usize_list("scales").unwrap(), vec![2, 4, 8]);
        let p = spec.parse(&args(&[])).unwrap();
        assert!(p.usize_list("scales").unwrap().is_empty());
        let p = spec.parse(&args(&["--scales", "2,x"])).unwrap();
        assert!(p.usize_list("scales").is_err());
    }

    #[test]
    fn help_returns_usage() {
        let e = spec().parse(&args(&["--help"])).unwrap_err();
        assert!(e.contains("USAGE"));
        assert!(e.contains("--nodes"));
    }
}
