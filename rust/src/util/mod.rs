//! Dependency-free utility layer: PRNG, units, statistics, JSON, tables,
//! CLI parsing, logging. Everything above `util` is domain code.

pub mod cli;
pub mod error;
pub mod fsio;
pub mod hash;
pub mod json;
pub mod logger;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;

pub use fsio::ensure_parent_dir;
pub use json::Json;
pub use rng::Rng;
pub use stats::{Samples, Summary};
pub use table::Table;
