//! Synthetic N-to-1 workloads (§6.1): Table 7 parameters, Table 8
//! configurations, access-pattern generators, and the DES driver that
//! executes them on any consistency layer.

pub mod driver;
pub mod spec;

pub use driver::{
    build_fs, build_fs_with, policy_layer, LayerFactory, LazyMake, PhaseReport, SyntheticDriver,
};
pub use spec::{Config, Pattern, WorkloadParams, WriteShuffle};
