//! The DES workload driver: executes a [`WorkloadParams`] program on a
//! chosen consistency layer against the real BaseFS functional state,
//! feeding per-op virtual-time costs to the simulation engine and
//! recording phase bandwidths.
//!
//! Per-rank program (the paper's two-phase N-to-1 workload, §6.1):
//!
//! ```text
//! writers: write × m_w → end_write_phase (commit/session_close) ─┐
//! readers: (idle)                                                ├ barrier
//! writers: done                                                  │
//! readers: begin_read_phase (session_open) → read × m_r → done ◄─┘
//! ```

use super::spec::{WorkloadParams, WriteShuffle};
use crate::basefs::{DesFabric, FabricCounters, FileId, SharedBb};
use crate::config::RunConfig;
use crate::fs::{FsKind, PolicyFs, WorkloadFs};
use crate::interval::Range;
use crate::sim::{Cluster, Driver, Engine, FaultEvent, Ns, SimOp};
use crate::util::rng::Rng;

/// Per-rank layer constructor — how drivers build their FS stacks.
/// Production code always uses the [`PolicyFs`] factory via
/// [`build_fs`]; the differential-pin tests pass
/// `crate::fs::legacy::build` to run the frozen reference layers
/// through the identical driver machinery.
pub type LayerFactory<'a> = &'a dyn Fn(FsKind, u32, SharedBb) -> Box<dyn WorkloadFs>;

/// `'static` layer constructor for lazy mode (slots are built mid-run,
/// so the factory cannot borrow).
pub type LazyMake = fn(FsKind, u32, SharedBb) -> Box<dyn WorkloadFs>;

/// The default production layer: one policy-interpreted [`PolicyFs`].
pub fn policy_layer(kind: FsKind, id: u32, bb: SharedBb) -> Box<dyn WorkloadFs> {
    Box::new(PolicyFs::new(kind, id, bb))
}

/// Build one policy-interpreted consistency layer per rank over the
/// fabric's BB stores — works for ANY registered model, including ones
/// defined only in a `[model.<name>]` config block.
pub fn build_fs(kind: FsKind, fabric: &DesFabric) -> Vec<Box<dyn WorkloadFs>> {
    build_fs_with(&policy_layer, kind, fabric)
}

/// [`build_fs`] with an explicit per-rank layer factory.
pub fn build_fs_with(
    make: LayerFactory,
    kind: FsKind,
    fabric: &DesFabric,
) -> Vec<Box<dyn WorkloadFs>> {
    (0..fabric.nranks())
        .map(|r| {
            let id = r as u32;
            make(kind, id, fabric.bb_of(id))
        })
        .collect()
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Stage {
    Write(usize),
    EndWrite,
    Barrier,
    BeginRead,
    Read(usize),
    Finish,
    Finished,
}

/// Phase timing + bandwidth report for one run.
#[derive(Debug, Clone)]
pub struct PhaseReport {
    pub fs: &'static str,
    pub write_bytes: u64,
    pub read_bytes: u64,
    /// Virtual time at which the last writer finished its sync.
    pub write_end: Ns,
    /// Virtual times bounding the read phase.
    pub read_start: Ns,
    pub read_end: Ns,
    pub makespan: Ns,
    pub rpcs: u64,
    /// Full fabric traffic counters (`rpcs` above is kept as the
    /// historical shorthand for `counters.rpcs`).
    pub counters: FabricCounters,
    /// DES events executed by the engine for this run.
    pub sim_ops: u64,
}

impl PhaseReport {
    /// Aggregate write bandwidth (bytes/s), as in Fig 3.
    pub fn write_bw(&self) -> f64 {
        if self.write_bytes == 0 || self.write_end == Ns::ZERO {
            return 0.0;
        }
        self.write_bytes as f64 / self.write_end.as_secs_f64()
    }

    /// Aggregate read bandwidth (bytes/s), as in Figs 4–6.
    pub fn read_bw(&self) -> f64 {
        if self.read_bytes == 0 || self.read_end <= self.read_start {
            return 0.0;
        }
        self.read_bytes as f64 / (self.read_end - self.read_start).as_secs_f64()
    }
}

/// The driver itself. One instance per run.
pub struct SyntheticDriver {
    pub fabric: DesFabric,
    /// Per-rank consistency layers. Eager mode (the historical, byte-
    /// compatible path) fills every slot at construction; lazy mode
    /// builds a slot at the rank's first fs touch and drops it at
    /// `Done`, so peak layer state tracks live ranks, not total ranks.
    fs: Vec<Option<Box<dyn WorkloadFs>>>,
    /// `Some(factory)` switches on lazy mode.
    lazy_make: Option<LazyMake>,
    kind: FsKind,
    params: WorkloadParams,
    /// The shared files the dataset is striped over (len = params.files;
    /// one entry — the paper's N-to-1 layout — unless striping is on).
    /// Lazy mode fills this at the first rank's wake-up.
    files: Vec<FileId>,
    stage: Vec<Stage>,
    /// Streaming plan state: offsets are generated on demand from
    /// `(seed, rank, i)` instead of the per-rank offset vectors PR 4
    /// materialized (O(nranks * m) words). The shared write shuffle is
    /// empty unless the write pattern is Random; `read_rng` holds one
    /// small generator per reader.
    shuffle: WriteShuffle,
    read_rng: Vec<Rng>,
    /// Reusable payload buffer (phantom fabric ignores content).
    payload: Vec<u8>,
    /// Reusable read destination — with `read_at_into` the read hot
    /// loop is allocation-free per access.
    read_buf: Vec<u8>,
    // metrics
    write_done_max: Ns,
    read_start_min: Ns,
    read_end_max: Ns,
}

impl SyntheticDriver {
    /// The unified constructor: one [`RunConfig`] in place of the
    /// historical `new` / `new_with_data` / `new_sharded` /
    /// `new_with_data_sharded` / `new_lazy` sprawl. The default config
    /// is exactly [`Self::new`]; every legacy constructor is now a thin
    /// shim over this path, pinned byte-for-bit by
    /// `run_config_matches_legacy_paths`.
    pub fn with_config(kind: FsKind, params: WorkloadParams, cfg: &RunConfig) -> Self {
        let make = cfg.layers.unwrap_or(policy_layer as LazyMake);
        if cfg.lazy {
            let nranks = params.nranks();
            let fabric = DesFabric::new_phantom_uniform(params.p, nranks, cfg.shards);
            let fs = (0..nranks).map(|_| None).collect();
            Self::assemble(kind, params, fabric, fs, Vec::new(), Some(make))
        } else {
            Self::new_with_layers(&make, kind, params, cfg.phantom, cfg.shards)
        }
    }

    /// Set up a run on `kind` with benchmark-scale (phantom) storage.
    /// Shim over [`Self::with_config`] — prefer that for new call sites.
    pub fn new(kind: FsKind, params: WorkloadParams) -> Self {
        Self::with_config(kind, params, &RunConfig::new())
    }

    /// Non-phantom variant for byte-exact integration tests.
    /// Shim over [`Self::with_config`] — prefer that for new call sites.
    pub fn new_with_data(kind: FsKind, params: WorkloadParams) -> Self {
        Self::with_config(kind, params, &RunConfig::new().phantom(false))
    }

    /// Phantom run against an N-shard metadata plane. `shards == 1`
    /// reproduces [`Self::new`] exactly (the refactor's anchor).
    /// Shim over [`Self::with_config`] — prefer that for new call sites.
    pub fn new_sharded(kind: FsKind, params: WorkloadParams, shards: usize) -> Self {
        Self::with_config(kind, params, &RunConfig::new().shards(shards))
    }

    /// Byte-exact run against an N-shard metadata plane.
    /// Shim over [`Self::with_config`] — prefer that for new call sites.
    pub fn new_with_data_sharded(kind: FsKind, params: WorkloadParams, shards: usize) -> Self {
        Self::with_config(kind, params, &RunConfig::new().phantom(false).shards(shards))
    }

    /// Lazy-layer variant for the 10^5–10^6-rank scale rows: no layer,
    /// plan, or file-open work happens at construction. Each rank's
    /// layer is built (and its dataset opens drained, matching the
    /// eager constructor) at the rank's first fs touch, and dropped the
    /// moment the rank reports `Done`, so peak layer state is bounded
    /// by the ranks actually live. Acquire-on-open models see opens at
    /// first touch rather than before the write phase, so this mode is
    /// opt-in and every legacy figure cell stays eager.
    /// Shim over [`Self::with_config`] — prefer that for new call sites.
    pub fn new_lazy(kind: FsKind, params: WorkloadParams, shards: usize) -> Self {
        Self::with_config(kind, params, &RunConfig::new().lazy(true).shards(shards))
    }

    /// [`Self::with_fabric`] with an explicit layer factory — the entry
    /// point of the differential pin (`tests/policy_differential.rs`),
    /// which runs the frozen legacy layers through the very same driver
    /// and asserts bit-for-bit equal reports.
    pub fn new_with_layers(
        make: LayerFactory,
        kind: FsKind,
        params: WorkloadParams,
        phantom: bool,
        shards: usize,
    ) -> Self {
        let nranks = params.nranks();
        let mut fabric = if phantom {
            DesFabric::new_phantom_uniform(params.p, nranks, shards)
        } else {
            DesFabric::new_uniform(params.p, nranks, shards)
        };
        let mut fs: Vec<Option<Box<dyn WorkloadFs>>> = build_fs_with(make, kind, &fabric)
            .into_iter()
            .map(Some)
            .collect();
        // Open the shared file(s) everywhere up front (the paper
        // measures the I/O phases, not the initial open). The single-
        // file path keeps its historical name so byte-exact runs stay
        // comparable across versions.
        let mut files = vec![0 as FileId; params.files.max(1)];
        for f in fs.iter_mut().flatten() {
            if params.files <= 1 {
                files[0] = f.open(&mut fabric, "/shared/nto1.dat");
            } else {
                for (i, slot) in files.iter_mut().enumerate() {
                    *slot = f.open(&mut fabric, &format!("/shared/nto1.{i}.dat"));
                }
            }
        }
        // Drop any costs from policy-specific opens (acquire-on-open
        // models refresh their snapshot at open).
        for r in 0..nranks {
            while fabric.pop_cost(r as u32).is_some() {}
        }
        Self::assemble(kind, params, fabric, fs, files, None)
    }

    fn assemble(
        kind: FsKind,
        params: WorkloadParams,
        fabric: DesFabric,
        fs: Vec<Option<Box<dyn WorkloadFs>>>,
        files: Vec<FileId>,
        lazy_make: Option<LazyMake>,
    ) -> Self {
        let nranks = params.nranks();
        let shuffle = params.write_shuffle();
        let read_rng = if params.read_pattern.is_some() {
            (0..params.n_readers()).map(|r| params.read_rng(r)).collect()
        } else {
            Vec::new()
        };
        let payload = vec![0u8; params.s as usize];
        Self {
            fabric,
            fs,
            lazy_make,
            kind,
            files,
            stage: (0..nranks)
                .map(|r| {
                    if params.is_writer(r) {
                        Stage::Write(0)
                    } else {
                        Stage::Barrier
                    }
                })
                .collect(),
            shuffle,
            read_rng,
            payload,
            read_buf: Vec::new(),
            params,
            write_done_max: Ns::ZERO,
            read_start_min: Ns(u64::MAX),
            read_end_max: Ns::ZERO,
        }
    }

    /// Does `rank` execute a read phase?
    fn has_reads(&self, rank: usize) -> bool {
        !self.params.is_writer(rank) && self.params.read_pattern.is_some() && self.params.m_r > 0
    }

    /// Lazy mode: build `rank`'s layer on first touch. The layer opens
    /// the shared dataset files (creating them if this is the first
    /// rank to wake) and its open-time costs are discarded, matching
    /// the eager constructor's post-open drain. Eager slots are always
    /// occupied, so this is a no-op there.
    fn ensure_fs(&mut self, rank: usize) {
        if self.fs[rank].is_some() {
            return;
        }
        let make = self.lazy_make.expect("eager fs slot vanished");
        let mut f = make(self.kind, rank as u32, self.fabric.bb_of(rank as u32));
        if self.files.is_empty() {
            if self.params.files <= 1 {
                self.files.push(f.open(&mut self.fabric, "/shared/nto1.dat"));
            } else {
                for i in 0..self.params.files {
                    let id = f.open(&mut self.fabric, &format!("/shared/nto1.{i}.dat"));
                    self.files.push(id);
                }
            }
        } else if self.params.files <= 1 {
            f.open(&mut self.fabric, "/shared/nto1.dat");
        } else {
            for i in 0..self.params.files {
                f.open(&mut self.fabric, &format!("/shared/nto1.{i}.dat"));
            }
        }
        while self.fabric.pop_cost(rank as u32).is_some() {}
        self.fs[rank] = Some(f);
    }

    /// Run to completion on a cluster and produce the report.
    pub fn run(self, cluster: Cluster) -> PhaseReport {
        self.run_cfg(cluster, &RunConfig::new())
    }

    /// [`Self::run`] on the windowed parallel event loop (`threads <= 1`
    /// is exactly the serial loop; any P is byte-identical to it).
    pub fn run_with_threads(self, cluster: Cluster, threads: usize) -> PhaseReport {
        self.run_cfg(cluster, &RunConfig::new().engine_threads(threads))
    }

    /// The unified runner: honours `cfg.engine_threads` and schedules
    /// `cfg.faults` into the engine's serialized commit loop. A
    /// non-empty plan switches the fabric into fault-aware mode with
    /// the model's own recovery obligation (replay-to-SC models replay
    /// surviving attachments at shard restart; permitted-stale models
    /// only fence leases); the empty plan stays on the exact historical
    /// pricing path.
    pub fn run_cfg(mut self, cluster: Cluster, cfg: &RunConfig) -> PhaseReport {
        if let Some(repl) = &cfg.replication {
            if !self.fabric.replication_enabled() {
                // The ack mode is the model's write_ack axis: how many
                // replicas a publishing mutation must reach before its
                // ack returns. The replica topology is run config, and
                // `--write-ack` (the ablation sweep) may override the
                // model's own axis per run.
                let ack = cfg.write_ack.unwrap_or_else(|| self.kind.write_ack());
                self.fabric.enable_replication(repl.clone(), ack.acked_replicas(repl.replicas));
            }
        }
        if !cfg.faults.is_empty() && !self.fabric.faults_enabled() {
            self.fabric.enable_faults_with(
                self.kind.recovery_obligation().replays(),
                cfg.faults.backoff,
            );
        }
        let mut engine = Engine::uniform_with(cluster, self.params.p, self.params.nranks());
        let stats = engine
            .run_threaded_with_plan(&mut self, cfg.engine_threads, &cfg.faults)
            .expect("synthetic workload deadlock");
        PhaseReport {
            fs: self.kind.name(),
            write_bytes: self.params.total_write_bytes(),
            read_bytes: self.params.total_read_bytes(),
            write_end: self.write_done_max,
            read_start: if self.read_start_min == Ns(u64::MAX) {
                Ns::ZERO
            } else {
                self.read_start_min
            },
            read_end: self.read_end_max,
            makespan: stats.makespan,
            rpcs: self.fabric.counters.rpcs,
            counters: self.fabric.counters,
            sim_ops: stats.ops_executed,
        }
    }
}

impl Driver for SyntheticDriver {
    /// Scheduled fault delivery: the engine calls this at the
    /// serialized commit point (identical order for any thread count),
    /// and the fabric applies the kill/restart — lease fencing, state
    /// wipe, and the model's recovery replay.
    fn on_fault(&mut self, ev: &FaultEvent) {
        self.fabric.apply_fault(ev);
    }

    /// One functional step per call; its fabric costs are drained
    /// straight into `out` as one batch (one heap event per step).
    fn next_ops(&mut self, rank: usize, now: Ns, out: &mut Vec<SimOp>) {
        // Advance the durability plane's clock: background replication
        // that has landed by `now` applies before this rank's step.
        // The engine invokes drivers at the serialized commit point in
        // global time order, so the landing order — and therefore every
        // replica's state — is identical for any engine thread count.
        // No-op (one null check) when replication is off.
        self.fabric.set_now(now);
        loop {
            match self.stage[rank] {
                Stage::Write(i) => {
                    if i < self.params.m_w {
                        self.ensure_fs(rank);
                        let off = self.params.write_offset_at(&self.shuffle, rank, i);
                        let (fidx, off) = self.params.locate(off);
                        self.fs[rank]
                            .as_mut()
                            .expect("writer layer missing")
                            .write_at(&mut self.fabric, self.files[fidx], off, &self.payload)
                            .expect("write failed");
                        self.stage[rank] = Stage::Write(i + 1);
                        self.fabric.drain_costs_into(rank as u32, out);
                        if !out.is_empty() {
                            return;
                        }
                    } else {
                        self.stage[rank] = Stage::EndWrite;
                    }
                }
                Stage::EndWrite => {
                    // Batched across files: one sync RPC per metadata
                    // shard touched (files-with-no-writes are skipped by
                    // the layer).
                    self.ensure_fs(rank);
                    let files = self.files.clone();
                    self.fs[rank]
                        .as_mut()
                        .expect("writer layer missing")
                        .end_write_phase_all(&mut self.fabric, &files)
                        .expect("end_write_phase failed");
                    self.stage[rank] = Stage::Barrier;
                    self.fabric.drain_costs_into(rank as u32, out);
                    if !out.is_empty() {
                        return;
                    }
                }
                Stage::Barrier => {
                    self.stage[rank] = Stage::BeginRead;
                    out.push(SimOp::Barrier);
                    return;
                }
                Stage::BeginRead => {
                    // Barrier released: the write phase is globally over.
                    self.write_done_max = self.write_done_max.max(now);
                    if !self.has_reads(rank) {
                        self.stage[rank] = Stage::Finish;
                    } else {
                        self.ensure_fs(rank);
                        let files = self.files.clone();
                        self.fs[rank]
                            .as_mut()
                            .expect("reader layer missing")
                            .begin_read_phase_all(&mut self.fabric, &files)
                            .expect("begin_read_phase failed");
                        self.read_start_min = self.read_start_min.min(now);
                        self.stage[rank] = Stage::Read(0);
                        self.fabric.drain_costs_into(rank as u32, out);
                        if !out.is_empty() {
                            return;
                        }
                    }
                }
                Stage::Read(i) => {
                    if i < self.params.m_r {
                        let ridx = rank - self.params.n_writers();
                        let off = self.params.read_offset_at(ridx, i, &mut self.read_rng[ridx]);
                        let (fidx, off) = self.params.locate(off);
                        self.read_buf.clear();
                        self.fs[rank]
                            .as_mut()
                            .expect("reader layer missing")
                            .read_at_into(
                                &mut self.fabric,
                                self.files[fidx],
                                Range::at(off, self.params.s),
                                &mut self.read_buf,
                            )
                            .expect("read failed");
                        debug_assert_eq!(self.read_buf.len() as u64, self.params.s);
                        self.stage[rank] = Stage::Read(i + 1);
                        self.fabric.drain_costs_into(rank as u32, out);
                        if !out.is_empty() {
                            return;
                        }
                    } else {
                        self.stage[rank] = Stage::Finish;
                    }
                }
                Stage::Finish => {
                    if self.has_reads(rank) {
                        self.read_end_max = self.read_end_max.max(now);
                    }
                    if self.lazy_make.is_some() {
                        // Lazy mode: release this rank's layer state the
                        // moment it leaves the simulation.
                        self.fs[rank] = None;
                    }
                    self.stage[rank] = Stage::Finished;
                    // Recovery costs queued while this rank was blocked
                    // (shard-restart fencing targets writers that never
                    // speak again) must be priced, not dropped. Healthy
                    // runs always reach here with an empty queue.
                    self.fabric.drain_costs_into(rank as u32, out);
                    out.push(SimOp::Done);
                    return;
                }
                Stage::Finished => unreachable!("rank {rank} scheduled after Done"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::Config;

    fn run(kind: FsKind, cfg: Config, n: usize, s: u64) -> PhaseReport {
        let params = cfg.params(n, 2, s, 4, 7);
        let driver = SyntheticDriver::new(kind, params);
        driver.run(Cluster::catalyst(n, 99))
    }

    #[test]
    fn write_only_runs_and_reports() {
        let rep = run(FsKind::COMMIT, Config::CnW, 2, 8 << 10);
        assert!(rep.write_bw() > 0.0);
        assert_eq!(rep.read_bytes, 0);
        assert_eq!(rep.read_bw(), 0.0);
        assert_eq!(rep.write_bytes, 2 * 2 * 4 * 8192);
    }

    #[test]
    fn session_and_commit_similar_on_writes() {
        // §6.1.1: write-only workloads perform ~the same under both.
        let a = run(FsKind::COMMIT, Config::CnW, 4, 8 << 20);
        let b = run(FsKind::SESSION, Config::CnW, 4, 8 << 20);
        let ratio = a.write_bw() / b.write_bw();
        assert!((0.9..1.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn cn_w_and_sn_w_similar() {
        // §6.1.1: BB buffering converts N-1 to N-N, pattern-independent.
        let a = run(FsKind::COMMIT, Config::CnW, 4, 8 << 20);
        let b = run(FsKind::COMMIT, Config::SnW, 4, 8 << 20);
        let ratio = a.write_bw() / b.write_bw();
        assert!((0.9..1.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn large_writes_approach_peak() {
        // 8 MiB writes should reach ~n × 1 GB/s aggregate.
        let n = 4;
        let rep = run(FsKind::SESSION, Config::CnW, n, 8 << 20);
        let per_node = rep.write_bw() / n as f64;
        assert!(
            per_node > 0.85e9,
            "per-node write bw {per_node} too far from SSD peak"
        );
    }

    #[test]
    fn small_reads_session_beats_commit() {
        // The paper's headline (Fig 4b): session ≫ commit for 8 KiB reads
        // at the paper's scale (12 procs/node, m = 10).
        let run_full = |kind| {
            let params = Config::CcR.params(8, 12, 8 << 10, 10, 7);
            SyntheticDriver::new(kind, params).run(Cluster::catalyst(8, 99))
        };
        let commit = run_full(FsKind::COMMIT);
        let session = run_full(FsKind::SESSION);
        assert!(
            session.read_bw() > 1.5 * commit.read_bw(),
            "session {} vs commit {}",
            session.read_bw(),
            commit.read_bw()
        );
        // And commit needs far more RPCs (one query per read).
        assert!(session.rpcs * 4 < commit.rpcs);
    }

    #[test]
    fn large_reads_models_comparable() {
        // Fig 4a: at 8 MiB the consistency model impact is negligible.
        let commit = run(FsKind::COMMIT, Config::CcR, 4, 8 << 20);
        let session = run(FsKind::SESSION, Config::CcR, 4, 8 << 20);
        let ratio = session.read_bw() / commit.read_bw();
        assert!((0.8..1.25).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn byte_exact_read_back_non_phantom() {
        // Non-phantom CC-R on session consistency: readers must see the
        // writers' bytes (zeros written => zeros read; the visibility
        // invariants are checked inside the FS layers).
        let params = Config::CcR.params(2, 2, 4096, 2, 3);
        let driver = SyntheticDriver::new_with_data(FsKind::SESSION, params);
        let rep = driver.run(Cluster::catalyst(2, 1));
        assert!(rep.read_bw() > 0.0);
    }

    #[test]
    fn posix_pays_per_write_rpcs() {
        // At scale the per-write attach RPCs saturate the global server's
        // master thread, throttling POSIX small writes.
        let run_full = |kind| {
            let params = Config::CnW.params(4, 12, 8 << 10, 10, 7);
            SyntheticDriver::new(kind, params).run(Cluster::catalyst(4, 99))
        };
        let posix = run_full(FsKind::POSIX);
        let commit = run_full(FsKind::COMMIT);
        assert!(posix.rpcs > commit.rpcs * 2);
        assert!(posix.write_bw() < commit.write_bw());
    }

    #[test]
    fn deterministic_reports() {
        let a = run(FsKind::SESSION, Config::CsR, 4, 8 << 10);
        let b = run(FsKind::SESSION, Config::CsR, 4, 8 << 10);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.rpcs, b.rpcs);
    }

    #[test]
    fn one_shard_is_bit_for_bit_the_unsharded_engine() {
        // Regression anchor: `new` and `new_sharded(.., 1)` must stay
        // the same code path forever (a future shards>1-only "fast
        // path" that drifts 1-shard behavior trips this). The
        // pre-refactor equivalence itself is pinned elsewhere:
        // `singleton_batch_prices_identically_to_single_rpc` (fabric)
        // proves the new batched sync path emits the historical per-file
        // SimOps/counters, and tests/shard_plane.rs proves plane
        // responses are shard-count-independent.
        for kind in [FsKind::COMMIT, FsKind::SESSION, FsKind::POSIX] {
            let params = Config::CcR.params(4, 4, 8 << 10, 6, 7);
            let old = SyntheticDriver::new(kind, params.clone())
                .run(Cluster::catalyst(4, 99));
            let new = SyntheticDriver::new_sharded(kind, params, 1)
                .run(Cluster::catalyst(4, 99));
            assert_eq!(old.makespan, new.makespan, "{kind:?}");
            assert_eq!(old.rpcs, new.rpcs, "{kind:?}");
            assert_eq!(old.write_end, new.write_end, "{kind:?}");
            assert_eq!(old.read_start, new.read_start, "{kind:?}");
            assert_eq!(old.read_end, new.read_end, "{kind:?}");
        }
    }

    #[test]
    fn sharding_helps_commit_small_reads_on_striped_files() {
        use crate::sim::{NetParams, ServerParams, SsdParams, UpfsParams};
        let run_sharded = |shards: usize| {
            let params = Config::CcR.params(8, 8, 8 << 10, 10, 7).with_files(16);
            let cluster = Cluster::new(
                8,
                SsdParams::catalyst(),
                NetParams::ib_qdr(),
                ServerParams::catalyst_sharded(shards),
                UpfsParams::catalyst_lustre(),
                99,
            );
            SyntheticDriver::new_sharded(FsKind::COMMIT, params, shards)
                .run(cluster)
                .read_bw()
        };
        let one = run_sharded(1);
        let eight = run_sharded(8);
        assert!(
            eight > 1.2 * one,
            "8 shards {eight} should beat 1 shard {one} on per-read queries"
        );
    }

    #[test]
    fn lazy_layers_match_eager_reports() {
        // Lazy mode defers layer construction and dataset opens to each
        // rank's first touch; for the paper models (whose visibility is
        // carried by sync/session boundaries, not open-time state) the
        // priced run must be indistinguishable from the eager path.
        for kind in [FsKind::COMMIT, FsKind::SESSION] {
            let params = Config::CcR.params(4, 2, 8 << 10, 4, 7);
            let eager = SyntheticDriver::new(kind, params.clone()).run(Cluster::catalyst(4, 99));
            let lazy =
                SyntheticDriver::new_lazy(kind, params, 1).run(Cluster::catalyst(4, 99));
            assert_eq!(eager.makespan, lazy.makespan, "{kind:?}");
            assert_eq!(eager.counters, lazy.counters, "{kind:?}");
            assert_eq!(eager.sim_ops, lazy.sim_ops, "{kind:?}");
            assert_eq!(eager.write_end, lazy.write_end, "{kind:?}");
            assert_eq!(eager.read_end, lazy.read_end, "{kind:?}");
        }
    }

    #[test]
    fn threaded_run_matches_serial_report() {
        for threads in [2, 8] {
            let params = Config::CcR.params(4, 2, 8 << 10, 4, 7);
            let serial = SyntheticDriver::new(FsKind::COMMIT, params.clone())
                .run(Cluster::catalyst(4, 99));
            let par = SyntheticDriver::new(FsKind::COMMIT, params)
                .run_with_threads(Cluster::catalyst(4, 99), threads);
            assert_eq!(serial.makespan, par.makespan, "threads={threads}");
            assert_eq!(serial.counters, par.counters, "threads={threads}");
            assert_eq!(serial.sim_ops, par.sim_ops, "threads={threads}");
        }
    }

    #[test]
    fn striped_files_byte_exact_read_back() {
        // Non-phantom CC-R over 4 files and 4 shards: the visibility
        // invariants (reader sees writer bytes) must survive striping.
        let params = Config::CcR.params(2, 2, 4096, 4, 3).with_files(4);
        for kind in [FsKind::SESSION, FsKind::COMMIT] {
            let driver = SyntheticDriver::new_with_data_sharded(kind, params.clone(), 4);
            let rep = driver.run(Cluster::catalyst(2, 1));
            assert!(rep.read_bw() > 0.0, "{kind:?}");
        }
    }

    #[test]
    fn run_config_matches_legacy_paths() {
        // The constructor-sprawl collapse: every legacy constructor is
        // a shim over `with_config`, and the explicit RunConfig spelling
        // must be byte-for-bit the legacy call it replaces.
        let params = Config::CcR.params(4, 2, 8 << 10, 4, 7);

        let old = SyntheticDriver::new(FsKind::COMMIT, params.clone()).run(Cluster::catalyst(4, 99));
        let cfg = RunConfig::new();
        let new = SyntheticDriver::with_config(FsKind::COMMIT, params.clone(), &cfg)
            .run_cfg(Cluster::catalyst(4, 99), &cfg);
        assert_eq!(old.makespan, new.makespan);
        assert_eq!(old.counters, new.counters);
        assert_eq!(old.sim_ops, new.sim_ops);

        let old = SyntheticDriver::new_lazy(FsKind::SESSION, params.clone(), 2)
            .run(Cluster::catalyst(4, 99));
        let cfg = RunConfig::new().lazy(true).shards(2);
        let new = SyntheticDriver::with_config(FsKind::SESSION, params.clone(), &cfg)
            .run_cfg(Cluster::catalyst(4, 99), &cfg);
        assert_eq!(old.makespan, new.makespan);
        assert_eq!(old.counters, new.counters);

        let params2 = Config::CcR.params(2, 2, 4096, 2, 3);
        let old = SyntheticDriver::new_with_data_sharded(FsKind::COMMIT, params2.clone(), 2)
            .run_with_threads(Cluster::catalyst(2, 1), 4);
        let cfg = RunConfig::new().phantom(false).shards(2).engine_threads(4);
        let new = SyntheticDriver::with_config(FsKind::COMMIT, params2, &cfg)
            .run_cfg(Cluster::catalyst(2, 1), &cfg);
        assert_eq!(old.makespan, new.makespan);
        assert_eq!(old.counters, new.counters);
    }

    #[test]
    fn shard_outage_prices_recovery_and_preserves_read_back() {
        use crate::sim::FaultPlan;
        // Probe the healthy run for the barrier-release time, then kill
        // the lone shard 1 ns before release and restart it exactly at
        // release. Recovery (lease fencing + attachment replay) runs
        // before any reader acquires, so the replay-to-SC session model
        // still hands readers the writers' bytes; the fencing/replay
        // RPCs are priced into the writers' tails.
        let params = Config::CcR.params(2, 2, 4096, 2, 3);
        let base = SyntheticDriver::new_with_data(FsKind::SESSION, params.clone())
            .run(Cluster::catalyst(2, 1));
        assert!(base.write_end > Ns(1));
        let plan = FaultPlan::shard_outage(0, base.write_end - Ns(1), base.write_end);
        let cfg = RunConfig::new().phantom(false).faults(plan);
        let faulted = SyntheticDriver::with_config(FsKind::SESSION, params, &cfg)
            .run_cfg(Cluster::catalyst(2, 1), &cfg);
        assert!(faulted.read_bw() > 0.0);
        assert!(
            faulted.counters.fenced_rpcs > 0,
            "writers must re-acquire fenced leases: {:?}",
            faulted.counters
        );
        assert!(faulted.counters.replayed_intervals > 0);
        assert!(faulted.makespan >= base.makespan);
    }

    #[test]
    fn faulted_runs_are_thread_count_invariant() {
        use crate::sim::FaultPlan;
        // Faults fire at the serialized commit point, so a faulted run
        // must stay byte-identical across engine thread counts.
        let params = Config::CcR.params(4, 2, 8 << 10, 4, 7);
        let base = SyntheticDriver::new(FsKind::COMMIT, params.clone()).run(Cluster::catalyst(4, 99));
        let plan = FaultPlan::shard_outage(0, base.write_end - Ns(1), base.write_end);
        let run_p = |threads: usize| {
            let cfg = RunConfig::new().faults(plan.clone()).engine_threads(threads);
            SyntheticDriver::with_config(FsKind::COMMIT, params.clone(), &cfg)
                .run_cfg(Cluster::catalyst(4, 99), &cfg)
        };
        let serial = run_p(1);
        let par = run_p(4);
        assert_eq!(serial.makespan, par.makespan);
        assert_eq!(serial.counters, par.counters);
        assert_eq!(serial.sim_ops, par.sim_ops);
        assert!(serial.counters.fenced_rpcs > 0, "{:?}", serial.counters);
    }
}
