//! Synthetic workload specification — Table 7 parameters, Table 8
//! configurations, and the three within-file access patterns of §6.1
//! (contiguous, strided, random). All processes share one file (N-to-1).

use crate::util::rng::Rng;

/// Within-file access pattern (§6.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// Each process accesses one gap-free region; regions are adjacent.
    Contiguous,
    /// Processes interleave with a fixed stride of (nprocs * s).
    Strided,
    /// Uniform random s-aligned offsets within the file extent.
    Random,
}

impl Pattern {
    pub fn name(&self) -> &'static str {
        match self {
            Pattern::Contiguous => "contiguous",
            Pattern::Strided => "strided",
            Pattern::Random => "random",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "contiguous" | "c" => Ok(Pattern::Contiguous),
            "strided" | "s" => Ok(Pattern::Strided),
            "random" | "r" => Ok(Pattern::Random),
            other => Err(format!("unknown pattern `{other}`")),
        }
    }
}

/// Table 7: the parameters of the synthetic I/O workloads.
#[derive(Debug, Clone)]
pub struct WorkloadParams {
    /// Number of writing nodes (all their processes only write).
    pub n_w: usize,
    /// Number of reading nodes (all their processes only read).
    pub n_r: usize,
    /// Processes per node.
    pub p: usize,
    /// Writes per writing process.
    pub m_w: usize,
    /// Reads per reading process.
    pub m_r: usize,
    /// Access size of every I/O operation, bytes.
    pub s: u64,
    pub write_pattern: Pattern,
    /// None for write-only workloads.
    pub read_pattern: Option<Pattern>,
    /// Seed for Random patterns.
    pub seed: u64,
    /// Number of shared files the dataset is striped over (block
    /// `b = offset / s` lives in file `b % files`). 1 = the paper's
    /// N-to-1 single shared file; larger values spread metadata across
    /// shards of the sharded plane (the `ablate_sharding` bench).
    pub files: usize,
}

impl WorkloadParams {
    /// Total nodes n = n_w + n_r.
    pub fn nodes(&self) -> usize {
        self.n_w + self.n_r
    }

    pub fn n_writers(&self) -> usize {
        self.n_w * self.p
    }

    pub fn n_readers(&self) -> usize {
        self.n_r * self.p
    }

    pub fn nranks(&self) -> usize {
        self.nodes() * self.p
    }

    /// Shared-file extent produced by the write phase.
    pub fn file_extent(&self) -> u64 {
        self.n_writers() as u64 * self.m_w as u64 * self.s
    }

    pub fn total_write_bytes(&self) -> u64 {
        self.file_extent()
    }

    pub fn total_read_bytes(&self) -> u64 {
        self.n_readers() as u64 * self.m_r as u64 * self.s
    }

    /// Is rank a writer? Ranks [0, n_w*p) live on writing nodes.
    pub fn is_writer(&self, rank: usize) -> bool {
        rank < self.n_writers()
    }

    /// Stripe the dataset over `files` shared files (builder style).
    pub fn with_files(mut self, files: usize) -> Self {
        self.files = files.max(1);
        self
    }

    /// Map a global dataset offset to (file index, offset within that
    /// file). Blocks are striped round-robin so every writer/reader pair
    /// agrees on placement and CC-R/CS-R visibility is preserved
    /// file-by-file. Identity for `files == 1`.
    pub fn locate(&self, offset: u64) -> (usize, u64) {
        if self.files <= 1 {
            return (0, offset);
        }
        let f = self.files as u64;
        let block = offset / self.s;
        let within = offset % self.s;
        ((block % f) as usize, (block / f) * self.s + within)
    }

    /// Offsets written by writer index `w` (0-based among writers).
    pub fn write_offsets(&self, w: usize) -> Vec<u64> {
        debug_assert!(w < self.n_writers());
        let nw = self.n_writers() as u64;
        let m = self.m_w as u64;
        match self.write_pattern {
            Pattern::Contiguous => (0..m).map(|i| (w as u64 * m + i) * self.s).collect(),
            Pattern::Strided => (0..m).map(|i| (i * nw + w as u64) * self.s).collect(),
            Pattern::Random => {
                // Disjoint randomization: permute the global block ids so
                // writers never overlap (overlap would be a storage race).
                let blocks = nw * m;
                let mut ids: Vec<u64> = (0..blocks).collect();
                let mut rng = Rng::seed_from_u64(self.seed ^ WRITE_SHUFFLE_SALT);
                rng.shuffle(&mut ids);
                ids[(w as u64 * m) as usize..((w as u64 + 1) * m) as usize]
                    .iter()
                    .map(|&b| b * self.s)
                    .collect()
            }
        }
    }

    /// Offsets read by reader index `r` (0-based among readers).
    pub fn read_offsets(&self, r: usize) -> Vec<u64> {
        debug_assert!(r < self.n_readers());
        let nr = self.n_readers() as u64;
        let m = self.m_r as u64;
        let extent_blocks = (self.file_extent() / self.s).max(1);
        match self.read_pattern.expect("read phase not configured") {
            Pattern::Contiguous => (0..m)
                .map(|i| ((r as u64 * m + i) % extent_blocks) * self.s)
                .collect(),
            Pattern::Strided => (0..m)
                .map(|i| ((i * nr + r as u64) % extent_blocks) * self.s)
                .collect(),
            Pattern::Random => {
                let mut rng = Rng::seed_from_u64(self.seed ^ READ_SALT ^ (r as u64));
                (0..m)
                    .map(|_| rng.gen_range_u64(extent_blocks) * self.s)
                    .collect()
            }
        }
    }

    /// Shared state for streaming write-offset generation. Only
    /// `Pattern::Random` carries real state — the global disjoint block
    /// permutation, computed once and shared by every writer. The other
    /// patterns are pure arithmetic and the shuffle is empty, so a run
    /// over 10^6 contiguous/strided writers allocates nothing here.
    pub fn write_shuffle(&self) -> WriteShuffle {
        match self.write_pattern {
            Pattern::Random => {
                let blocks = self.n_writers() as u64 * self.m_w as u64;
                let mut ids: Vec<u64> = (0..blocks).collect();
                let mut rng = Rng::seed_from_u64(self.seed ^ WRITE_SHUFFLE_SALT);
                rng.shuffle(&mut ids);
                WriteShuffle(Some(ids))
            }
            _ => WriteShuffle(None),
        }
    }

    /// The `i`-th offset written by writer `w` — streaming counterpart
    /// of `write_offsets`, equal element-for-element for the same
    /// parameters (pinned by `streaming_write_offsets_match_materialized`).
    pub fn write_offset_at(&self, shuffle: &WriteShuffle, w: usize, i: usize) -> u64 {
        debug_assert!(w < self.n_writers());
        debug_assert!(i < self.m_w);
        let nw = self.n_writers() as u64;
        let (w, i, m) = (w as u64, i as u64, self.m_w as u64);
        match self.write_pattern {
            Pattern::Contiguous => (w * m + i) * self.s,
            Pattern::Strided => (i * nw + w) * self.s,
            Pattern::Random => {
                let ids = shuffle.0.as_ref().expect("random writes need write_shuffle()");
                ids[(w * m + i) as usize] * self.s
            }
        }
    }

    /// Per-reader RNG for streaming `Pattern::Random` reads. Pass it to
    /// `read_offset_at` with `i` advancing sequentially from 0; the
    /// non-random patterns never draw from it.
    pub fn read_rng(&self, r: usize) -> Rng {
        Rng::seed_from_u64(self.seed ^ READ_SALT ^ (r as u64))
    }

    /// The `i`-th offset read by reader `r` — streaming counterpart of
    /// `read_offsets`. For `Pattern::Random` the rng must come from
    /// `read_rng(r)` and calls must advance `i` sequentially from 0.
    pub fn read_offset_at(&self, r: usize, i: usize, rng: &mut Rng) -> u64 {
        debug_assert!(r < self.n_readers());
        debug_assert!(i < self.m_r);
        let nr = self.n_readers() as u64;
        let (r, i, m) = (r as u64, i as u64, self.m_r as u64);
        let extent_blocks = (self.file_extent() / self.s).max(1);
        match self.read_pattern.expect("read phase not configured") {
            Pattern::Contiguous => ((r * m + i) % extent_blocks) * self.s,
            Pattern::Strided => ((i * nr + r) % extent_blocks) * self.s,
            Pattern::Random => rng.gen_range_u64(extent_blocks) * self.s,
        }
    }
}

/// Opaque shared state for `write_offset_at` — see
/// [`WorkloadParams::write_shuffle`]. Empty for non-random patterns.
#[derive(Debug, Clone)]
pub struct WriteShuffle(Option<Vec<u64>>);

/// Salt separating the write-shuffle RNG stream from read streams.
const WRITE_SHUFFLE_SALT: u64 = 0x77ab_cdef_1234_5678;

/// Salt separating per-reader random-read RNG streams.
const READ_SALT: u64 = 0x5eed_0000_0000_0000;

/// Table 8: the four named configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Config {
    /// Contiguous N-to-1 write, no read phase.
    CnW,
    /// Strided N-to-1 write, no read phase.
    SnW,
    /// Contiguous write by n/2 nodes, contiguous read by n/2 nodes.
    CcR,
    /// Contiguous write by n/2 nodes, strided read by n/2 nodes.
    CsR,
}

impl Config {
    pub fn name(&self) -> &'static str {
        match self {
            Config::CnW => "CN-W",
            Config::SnW => "SN-W",
            Config::CcR => "CC-R",
            Config::CsR => "CS-R",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_uppercase().replace('_', "-").as_str() {
            "CN-W" | "CNW" => Ok(Config::CnW),
            "SN-W" | "SNW" => Ok(Config::SnW),
            "CC-R" | "CCR" => Ok(Config::CcR),
            "CS-R" | "CSR" => Ok(Config::CsR),
            other => Err(format!("unknown config `{other}` (CN-W|SN-W|CC-R|CS-R)")),
        }
    }

    /// Instantiate Table 8 with n total nodes, p procs/node, access size
    /// s, and m accesses per process (the paper used m_w = m_r = 10).
    pub fn params(&self, n: usize, p: usize, s: u64, m: usize, seed: u64) -> WorkloadParams {
        let (n_w, n_r, wp, rp) = match self {
            Config::CnW => (n, 0, Pattern::Contiguous, None),
            Config::SnW => (n, 0, Pattern::Strided, None),
            Config::CcR => (n / 2, n - n / 2, Pattern::Contiguous, Some(Pattern::Contiguous)),
            Config::CsR => (n / 2, n - n / 2, Pattern::Contiguous, Some(Pattern::Strided)),
        };
        WorkloadParams {
            n_w,
            n_r,
            p,
            m_w: m,
            m_r: m,
            s,
            write_pattern: wp,
            read_pattern: rp,
            seed,
            files: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(cfg: Config) -> WorkloadParams {
        cfg.params(4, 2, 1024, 3, 42)
    }

    #[test]
    fn cnw_layout() {
        let p = params(Config::CnW);
        assert_eq!(p.nranks(), 8);
        assert_eq!(p.n_writers(), 8);
        assert_eq!(p.n_readers(), 0);
        assert_eq!(p.write_offsets(0), vec![0, 1024, 2048]);
        assert_eq!(p.write_offsets(1), vec![3072, 4096, 5120]);
        assert_eq!(p.file_extent(), 8 * 3 * 1024);
    }

    #[test]
    fn snw_layout_interleaves() {
        let p = params(Config::SnW);
        // writer 0: blocks 0, 8, 16; writer 1: blocks 1, 9, 17...
        assert_eq!(p.write_offsets(0), vec![0, 8 * 1024, 16 * 1024]);
        assert_eq!(p.write_offsets(1), vec![1024, 9 * 1024, 17 * 1024]);
    }

    #[test]
    fn writers_cover_extent_exactly_once() {
        for cfg in [Config::CnW, Config::SnW] {
            let p = params(cfg);
            let mut all: Vec<u64> = (0..p.n_writers())
                .flat_map(|w| p.write_offsets(w))
                .collect();
            all.sort_unstable();
            let expect: Vec<u64> = (0..(p.file_extent() / p.s)).map(|b| b * p.s).collect();
            assert_eq!(all, expect, "cfg {}", cfg.name());
        }
    }

    #[test]
    fn random_writes_disjoint_and_cover() {
        let mut p = params(Config::CnW);
        p.write_pattern = Pattern::Random;
        let mut all: Vec<u64> = (0..p.n_writers())
            .flat_map(|w| p.write_offsets(w))
            .collect();
        all.sort_unstable();
        let expect: Vec<u64> = (0..(p.file_extent() / p.s)).map(|b| b * p.s).collect();
        assert_eq!(all, expect);
    }

    #[test]
    fn ccr_reader_maps_to_single_writer() {
        let p = params(Config::CcR); // 2 write nodes, 2 read nodes, p=2
        assert_eq!(p.n_writers(), 4);
        assert_eq!(p.n_readers(), 4);
        // reader j reads exactly writer j's blocks (m_r == m_w).
        for j in 0..4 {
            assert_eq!(p.read_offsets(j), p.write_offsets(j));
        }
    }

    #[test]
    fn csr_reader_strides_across_writers() {
        let p = params(Config::CsR);
        let r0 = p.read_offsets(0);
        // strided: blocks 0, 4, 8 (4 readers)
        assert_eq!(r0, vec![0, 4 * 1024, 8 * 1024]);
        // these blocks belong to writers 0, 1, 2 under contiguous writes
        // (3 blocks each): block 0 -> w0, block 4 -> w1, block 8 -> w2.
    }

    #[test]
    fn random_reads_within_extent_and_aligned() {
        let mut p = params(Config::CcR);
        p.read_pattern = Some(Pattern::Random);
        for j in 0..p.n_readers() {
            for off in p.read_offsets(j) {
                assert!(off < p.file_extent());
                assert_eq!(off % p.s, 0);
            }
        }
    }

    #[test]
    fn locate_stripes_blocks_bijectively() {
        let p = params(Config::CcR).with_files(3);
        // Every dataset block maps to a distinct (file, local offset)
        // slot, and files stay s-aligned and dense.
        let blocks = p.file_extent() / p.s;
        let mut seen = std::collections::BTreeSet::new();
        for b in 0..blocks {
            let (f, local) = p.locate(b * p.s);
            assert!(f < 3);
            assert_eq!(local % p.s, 0);
            assert!(seen.insert((f, local)), "slot collision at block {b}");
        }
        assert_eq!(seen.len() as u64, blocks);
        // Identity when unstriped.
        let p1 = params(Config::CcR);
        assert_eq!(p1.locate(5 * p1.s + 7), (0, 5 * p1.s + 7));
        // Non-aligned offsets keep their within-block remainder.
        let (f, local) = p.locate(4 * p.s + 100);
        assert_eq!(f, (4 % 3) as usize);
        assert_eq!(local, (4 / 3) * p.s + 100);
    }

    #[test]
    fn streaming_write_offsets_match_materialized() {
        for pat in [Pattern::Contiguous, Pattern::Strided, Pattern::Random] {
            let mut p = params(Config::SnW);
            p.write_pattern = pat;
            let shuffle = p.write_shuffle();
            for w in 0..p.n_writers() {
                let streamed: Vec<u64> = (0..p.m_w)
                    .map(|i| p.write_offset_at(&shuffle, w, i))
                    .collect();
                assert_eq!(streamed, p.write_offsets(w), "{} w{w}", pat.name());
            }
        }
    }

    #[test]
    fn streaming_read_offsets_match_materialized() {
        for pat in [Pattern::Contiguous, Pattern::Strided, Pattern::Random] {
            let mut p = params(Config::CcR);
            p.read_pattern = Some(pat);
            for r in 0..p.n_readers() {
                let mut rng = p.read_rng(r);
                let streamed: Vec<u64> = (0..p.m_r)
                    .map(|i| p.read_offset_at(r, i, &mut rng))
                    .collect();
                assert_eq!(streamed, p.read_offsets(r), "{} r{r}", pat.name());
            }
        }
    }

    #[test]
    fn non_random_write_shuffle_is_stateless() {
        let p = params(Config::CnW);
        // Contiguous/strided shuffles carry no allocation; the random
        // shuffle is one global permutation shared by every writer.
        assert!(p.write_shuffle().0.is_none());
    }

    #[test]
    fn config_parse() {
        assert_eq!(Config::parse("cc-r").unwrap(), Config::CcR);
        assert_eq!(Config::parse("CNW").unwrap(), Config::CnW);
        assert!(Config::parse("zz").is_err());
    }
}
