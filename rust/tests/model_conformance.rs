//! Conformance bridge: for **every registered model** — the paper's
//! four, the built-in extensions (`commit_strict`, `cto`, `eventual`),
//! and a model defined purely as config data — the *executable*
//! `PolicyFs` layer's observable outcomes must fall within the *formal*
//! model's allowed set:
//!
//! - a recorded execution that the race detector certifies race-free
//!   under the model's own Table-4 definition must return the unique
//!   sequentially-consistent outcome (readers see exactly the writers'
//!   bytes);
//! - a racy execution constrains nothing (any outcome is allowed), so
//!   the detector must flag it — which it does for e.g. `eventual`'s
//!   unsynchronized two-phase pattern.
//!
//! Plus the litmus suite replayed against every registered model, with
//! the weakest-model property (race-free under ANY registered model ⇒
//! race-free under POSIX) as a cross-model invariant.

use pscnf::basefs::TestFabric;
use pscnf::fs::{FsKind, PolicyFs, WorkloadFs};
use pscnf::interval::Range;
use pscnf::model::{litmus, race, SyncPolicy};
use pscnf::trace::{RecordingFs, SharedTrace};

/// Run the paper's two-phase pattern (write → end_write_phase →
/// barrier → begin_read_phase → read) on `kind`'s executable layer,
/// recording the formal trace. Returns (race-free under kind's own
/// model?, bytes the reader saw).
fn two_phase_recorded(kind: FsKind) -> (bool, Vec<u8>) {
    let payload = [0xABu8; 64];
    let mut fabric = TestFabric::new(2);
    let trace = SharedTrace::new();
    let mut w = RecordingFs::new(PolicyFs::new(kind, 0, fabric.bb_of(0)), trace.clone());
    let mut r = RecordingFs::new(PolicyFs::new(kind, 1, fabric.bb_of(1)), trace.clone());
    let f = w.open(&mut fabric, "/conf/two_phase.dat");
    r.open(&mut fabric, "/conf/two_phase.dat");

    w.write_at(&mut fabric, f, 0, &payload).unwrap();
    w.end_write_phase(&mut fabric, f).unwrap();
    // Clients buffer data ops; flush so the barrier scan sees each
    // rank's true last event (models without an end-write sync op
    // record nothing at the phase switch).
    w.flush();
    r.flush();
    trace.barrier(&[0, 1]);
    r.passed_barrier();
    r.begin_read_phase(&mut fabric, f).unwrap();
    let got = r.read_at(&mut fabric, f, Range::new(0, 64)).unwrap();

    drop(w);
    drop(r);
    let t = trace.finish();
    let rf = race::race_free(&t, &kind.model()).expect("acyclic");
    (rf, got)
}

/// THE bridge invariant, for every registered model (including any
/// registered by sibling tests in this binary): if the recorded
/// execution is race-free under the model's own formal definition, the
/// reader must have seen the unique SC outcome.
#[test]
fn race_free_two_phase_implies_sc_outcome_for_every_registered_model() {
    for kind in FsKind::registered() {
        let (race_free, got) = two_phase_recorded(kind);
        if race_free {
            assert_eq!(
                got,
                vec![0xABu8; 64],
                "model `{}`: formally race-free execution returned a non-SC outcome",
                kind.name()
            );
        }
    }
}

/// The built-ins land on the expected side of the race verdict: every
/// phase-synchronizing model certifies the two-phase pattern;
/// `eventual` (publication at close only) must flag it as racy — and
/// its reader indeed saw nothing, which the formal model allows.
#[test]
fn two_phase_verdicts_match_builtin_semantics() {
    for kind in [
        FsKind::POSIX,
        FsKind::COMMIT,
        FsKind::COMMIT_STRICT,
        FsKind::SESSION,
        FsKind::MPIIO,
        FsKind::CTO,
    ] {
        let (race_free, got) = two_phase_recorded(kind);
        assert!(race_free, "{} should certify the pattern", kind.name());
        assert_eq!(got, vec![0xABu8; 64], "{}", kind.name());
    }
    let (race_free, got) = two_phase_recorded(FsKind::EVENTUAL);
    assert!(!race_free, "eventual publishes nothing at phase end");
    assert_eq!(got, vec![0u8; 64], "nothing visible before the close");
}

/// `eventual` becomes properly synchronized when the writer CLOSES the
/// file (the close is the commit, and RecordingFs records it as such).
#[test]
fn eventual_close_certifies_and_publishes() {
    let kind = FsKind::EVENTUAL;
    let mut fabric = TestFabric::new(2);
    let trace = SharedTrace::new();
    let mut w = RecordingFs::new(PolicyFs::new(kind, 0, fabric.bb_of(0)), trace.clone());
    let mut r = RecordingFs::new(PolicyFs::new(kind, 1, fabric.bb_of(1)), trace.clone());
    let f = w.open(&mut fabric, "/conf/eventual.dat");
    r.open(&mut fabric, "/conf/eventual.dat");
    w.write_at(&mut fabric, f, 0, &[0x5Au8; 32]).unwrap();
    w.close(&mut fabric, f).unwrap();
    w.flush();
    r.flush();
    trace.barrier(&[0, 1]);
    r.passed_barrier();
    let got = r.read_at(&mut fabric, f, Range::new(0, 32)).unwrap();
    drop(w);
    drop(r);
    let t = trace.finish();
    assert!(race::race_free(&t, &kind.model()).unwrap());
    assert_eq!(got, vec![0x5Au8; 32]);
}

/// MPI-IO's open/close are formal sync ops too: a run synchronized
/// purely by close → barrier → open is race-free under MPI-IO (one of
/// the four MSCs) and readable — pinning the open/close recording.
#[test]
fn mpiio_close_open_msc_certifies() {
    let kind = FsKind::MPIIO;
    let mut fabric = TestFabric::new(2);
    let trace = SharedTrace::new();
    let mut w = RecordingFs::new(PolicyFs::new(kind, 0, fabric.bb_of(0)), trace.clone());
    let f = w.open(&mut fabric, "/conf/mpiio.dat");
    w.write_at(&mut fabric, f, 0, &[7u8; 16]).unwrap();
    w.close(&mut fabric, f).unwrap();
    w.flush();
    trace.barrier(&[0]);
    // Reader constructed AFTER the close: its MPI_File_open lands
    // post-barrier.
    let mut r = RecordingFs::new(PolicyFs::new(kind, 1, fabric.bb_of(1)), trace.clone());
    r.passed_barrier();
    let rf = r.open(&mut fabric, "/conf/mpiio.dat");
    let got = r.read_at(&mut fabric, rf, Range::new(0, 16)).unwrap();
    drop(w);
    drop(r);
    let t = trace.finish();
    assert!(race::race_free(&t, &kind.model()).unwrap());
    assert_eq!(got, vec![7u8; 16]);
}

/// An unsynchronized conflicting pair races under EVERY registered
/// model — no policy can talk its way out of a real race.
#[test]
fn unsynchronized_conflict_races_under_every_registered_model() {
    let mut fabric = TestFabric::new(2);
    let trace = SharedTrace::new();
    let kind = FsKind::POSIX; // layer irrelevant: no syncs, no barrier
    let mut w = RecordingFs::new(PolicyFs::new(kind, 0, fabric.bb_of(0)), trace.clone());
    let mut r = RecordingFs::new(PolicyFs::new(kind, 1, fabric.bb_of(1)), trace.clone());
    let f = w.open(&mut fabric, "/conf/racy.dat");
    r.open(&mut fabric, "/conf/racy.dat");
    w.write_at(&mut fabric, f, 0, &[1u8; 8]).unwrap();
    let _ = r.read_at(&mut fabric, f, Range::new(0, 8)).unwrap();
    drop(w);
    drop(r);
    let t = trace.finish();
    for kind in FsKind::registered() {
        assert!(
            !race::race_free(&t, &kind.model()).unwrap(),
            "model `{}` failed to flag an unsynchronized conflict",
            kind.name()
        );
    }
}

/// Litmus suite × every registered model, plus the weakest-model
/// property: any model's MSC edges all imply hb, so race-freedom under
/// ANY registered model implies race-freedom under POSIX's direct-hb
/// definition.
#[test]
fn litmus_suite_covers_every_registered_model_with_posix_weakest() {
    for l in litmus::all() {
        let posix_rf = race::race_free(&l.trace, &FsKind::POSIX.model()).unwrap();
        // litmus::run emits one row per registered model (snapshot at
        // call time — sibling tests may register more concurrently).
        let results = litmus::run(&l);
        assert!(results.len() >= 7, "rows for every built-in at least");
        for (name, races, _) in &results {
            if *races == 0 {
                assert!(
                    posix_rf,
                    "litmus `{}`: race-free under {name} but racy under POSIX",
                    l.name
                );
            }
        }
    }
}

/// The acceptance path end to end: a model that exists ONLY as config
/// data is registered, appears in the scenario registry's `model_ext`
/// family, runs through the bench runner, and conforms to its derived
/// formal definition like any built-in.
#[test]
fn config_only_model_runs_the_scenario_matrix_and_conforms() {
    let ini = pscnf::config::parse_ini(
        "[model.conf_lazy]\n\
         display = ConfLazy\n\
         publication = phase_end\n\
         acquisition = lifetime_snapshot\n",
    )
    .unwrap();
    let kinds = FsKind::register_from_ini(&ini).unwrap();
    assert_eq!(kinds.len(), 1);
    let kind = kinds[0];
    assert!(!kind.is_builtin());
    // Formal def derived from the policy: session-shaped MSC.
    assert_eq!(
        kind.model().mscs,
        SyncPolicy::session().derive_model("x").mscs
    );

    // The registry now carries model_ext cells for it — ungated
    // (non-smoke), because the CI baseline can't contain them.
    let cells: Vec<_> = pscnf::bench::registry()
        .into_iter()
        .filter(|s| s.family == "model_ext" && s.fs == kind)
        .collect();
    assert!(!cells.is_empty(), "no model_ext cells for conf_lazy");
    assert!(cells.iter().all(|s| !s.smoke));

    // Run its smallest read cell through the real bench runner.
    let mut cell = cells
        .iter()
        .find(|s| s.id.contains("CC-R.s/8KiB"))
        .expect("small CC-R cell")
        .clone();
    cell.repeats = 1;
    let rec = pscnf::bench::run_scenario(&cell);
    let bw = rec.metric_value("bw").unwrap();
    assert!(bw.is_finite() && bw > 0.0, "conf_lazy cell bw {bw}");
    assert_eq!(rec.params["fs"].as_str(), Some("conf_lazy"));

    // And the executable layer conforms to the derived formal model.
    let (race_free, got) = two_phase_recorded(kind);
    assert!(race_free, "conf_lazy two-phase should certify");
    assert_eq!(got, vec![0xABu8; 64]);
}
