//! Runner-parallelism guarantees: `pscnf bench --jobs N` must emit a
//! BENCH_matrix.json byte-identical to the serial run for the same
//! scenario filter, per-cell seeds must be independent of execution
//! order, and the wall-time sidecar must track the input order.
//!
//! The `perf_hotpath` and `check_matrix` families are the deliberate
//! exceptions: their cells time the simulator (or the race detector)
//! itself with a wall clock, so they are excluded from the
//! byte-identity property (and from the smoke sets used below) by
//! construction.

use pscnf::bench::{registry, run_matrix_timed, run_scenario, Kind, Scenario};
use pscnf::fs::FsKind;

/// The smoke family minus the wall-clock cells.
fn smoke_virtual() -> Vec<Scenario> {
    let v: Vec<Scenario> = registry()
        .into_iter()
        .filter(|s| {
            s.smoke && !matches!(s.kind, Kind::HotPath(_) | Kind::CheckMatrix { .. })
        })
        .collect();
    assert!(v.len() >= 8, "smoke set unexpectedly small: {}", v.len());
    v
}

#[test]
fn parallel_matrix_is_byte_identical_to_serial() {
    let scenarios = smoke_virtual();
    let (serial, _) = run_matrix_timed(&scenarios, 1);
    let (parallel, _) = run_matrix_timed(&scenarios, 8);
    assert_eq!(
        serial.to_json().pretty(),
        parallel.to_json().pretty(),
        "--jobs 8 must serialize byte-identically to --jobs 1"
    );
}

#[test]
fn cell_records_are_independent_of_execution_order() {
    // A small mixed subset (every workload driver represented): running
    // the cells reversed and in parallel must reproduce each record
    // bit-for-bit — per-cell seeds cannot depend on position or on what
    // ran before.
    let mut subset: Vec<Scenario> = smoke_virtual()
        .into_iter()
        .filter(|s| {
            s.fs == FsKind::SESSION
                || (s.fs == FsKind::COMMIT && s.id.contains("CC-R/8KiB"))
        })
        .collect();
    assert!(subset.len() >= 4);
    let (forward, _) = run_matrix_timed(&subset, 1);
    subset.reverse();
    let (reversed, _) = run_matrix_timed(&subset, 3);
    assert_eq!(forward.records.len(), reversed.records.len());
    for rec in &forward.records {
        let other = reversed
            .find(&rec.id)
            .unwrap_or_else(|| panic!("{} missing from reversed run", rec.id));
        assert_eq!(rec, other, "record {} depends on execution order", rec.id);
    }
    // And a lone rerun of a single cell matches its in-matrix record.
    let one = subset.last().unwrap();
    let solo = run_scenario(one);
    assert_eq!(reversed.find(&one.id), Some(&solo));
}

#[test]
fn wall_sidecar_tracks_input_order() {
    let scenarios: Vec<Scenario> = smoke_virtual()
        .into_iter()
        .filter(|s| s.fs == FsKind::POSIX)
        .collect();
    let (_, walls) = run_matrix_timed(&scenarios, 2);
    assert_eq!(walls.len(), scenarios.len());
    for (sc, (id, _)) in scenarios.iter().zip(&walls) {
        assert_eq!(&sc.id, id, "wall sidecar out of input order");
    }
    // Wall times are real measurements (nonzero), but never metrics.
    assert!(walls.iter().all(|&(_, ns)| ns > 0));
}

#[test]
fn hotpath_cells_report_simulator_throughput() {
    let cells: Vec<Scenario> = registry()
        .into_iter()
        .filter(|s| s.family == "perf_hotpath")
        .collect();
    assert_eq!(cells.len(), 7, "expected the seven hot-path cells");
    // One ns/op cell and the gated fig4cell events/s cell actually run.
    let mut attach = cells
        .iter()
        .find(|s| s.id.contains("gtree.attach"))
        .unwrap()
        .clone();
    attach.repeats = 1;
    let rec = run_scenario(&attach);
    let ns = rec.metric_value("ns_per_op").unwrap();
    assert!(ns.is_finite() && ns > 0.0, "gtree.attach ns/op {ns}");
    assert!(!rec.metrics["ns_per_op"].higher_is_better);

    let mut fig4 = cells
        .iter()
        .find(|s| s.id.contains("fig4cell"))
        .unwrap()
        .clone();
    assert!(fig4.smoke, "fig4cell must ride the gated smoke subset");
    // Shrink the cell so the test stays fast; the metric shape is what
    // is under test here.
    fig4.nodes = 2;
    fig4.ppn = 2;
    fig4.m = 2;
    fig4.repeats = 1;
    let rec = run_scenario(&fig4);
    let eps = rec.metric_value("events_per_sec").unwrap();
    assert!(eps.is_finite() && eps > 0.0, "fig4cell events/s {eps}");
    assert!(rec.metrics["events_per_sec"].higher_is_better);
}
