//! Registry-completeness guard: the smoke subset of the scenario matrix
//! must cover every consistency model × access pattern (and every
//! workload driver), and every one of those cells must actually run in
//! the DES engine and produce a finite, nonzero bandwidth — so no cell
//! of the matrix can silently drop out or degenerate to zero.

use pscnf::bench::{registry, run_scenario, Kind, Scenario};
use pscnf::fs::FsKind;
use pscnf::workload::Pattern;

fn smoke_set() -> Vec<Scenario> {
    let smoke: Vec<Scenario> = registry().into_iter().filter(|s| s.smoke).collect();
    assert!(!smoke.is_empty(), "registry has no smoke scenarios");
    smoke
}

#[test]
fn smoke_covers_every_model_pattern_and_workload() {
    let smoke = smoke_set();
    for fs in FsKind::PAPER {
        for pat in [Pattern::Contiguous, Pattern::Strided, Pattern::Random] {
            assert!(
                smoke.iter().any(|s| s.fs == fs && s.uses_pattern(pat)),
                "no smoke scenario covers {fs:?} × {pat:?}"
            );
        }
        assert!(
            smoke
                .iter()
                .any(|s| s.fs == fs && matches!(s.kind, Kind::Scr { .. })),
            "no SCR smoke scenario for {fs:?}"
        );
        assert!(
            smoke
                .iter()
                .any(|s| s.fs == fs && matches!(s.kind, Kind::Dl { .. })),
            "no DL smoke scenario for {fs:?}"
        );
    }
}

#[test]
fn every_smoke_cell_runs_with_finite_nonzero_bandwidth() {
    for sc in smoke_set() {
        let rec = run_scenario(&sc);
        assert_eq!(rec.id, sc.id);
        if matches!(sc.kind, Kind::HotPath(_)) {
            // Wall-clock cells report engine throughput, not simulated
            // bandwidth.
            let eps = rec
                .metric_value("events_per_sec")
                .or_else(|| rec.metric_value("ns_per_op"))
                .unwrap_or_else(|| panic!("hot-path cell {} emitted no metric", sc.id));
            assert!(
                eps.is_finite() && eps > 0.0,
                "hot-path cell {} produced {eps}",
                sc.id
            );
            continue;
        }
        if matches!(sc.kind, Kind::CheckMatrix { .. }) {
            // Wall-clock detector cells report ops checked per second.
            let ops = rec
                .metric_value("ops_checked_per_sec")
                .unwrap_or_else(|| panic!("check_matrix cell {} emitted no metric", sc.id));
            assert!(
                ops.is_finite() && ops > 0.0,
                "check_matrix cell {} produced {ops}",
                sc.id
            );
            continue;
        }
        let bw = rec
            .metric_value("bw")
            .unwrap_or_else(|| panic!("scenario {} emitted no bw metric", sc.id));
        assert!(
            bw.is_finite() && bw > 0.0,
            "scenario {} produced bandwidth {bw}",
            sc.id
        );
        let lat = rec.metric_value("lat_p95_s").unwrap();
        assert!(lat.is_finite() && lat > 0.0, "scenario {} lat {lat}", sc.id);
    }
}

#[test]
fn smoke_matrix_round_trips_through_json() {
    use pscnf::bench::BenchMatrix;
    // One cheap cell per model is enough to pin the end-to-end path the
    // CI perf-gate uses: run → dump → parse → byte-identical records.
    let cells: Vec<Scenario> = FsKind::PAPER
        .into_iter()
        .map(|fs| {
            smoke_set()
                .into_iter()
                .find(|s| s.fs == fs && s.id.contains("CC-R/8KiB"))
                .expect("CC-R smoke cell per model")
        })
        .collect();
    let matrix = pscnf::bench::run_matrix(&cells);
    assert_eq!(matrix.records.len(), 4);
    let back = BenchMatrix::parse(&matrix.to_json().pretty()).unwrap();
    assert_eq!(back, matrix);
    let rep = pscnf::bench::compare(&matrix, &back, 0.0);
    assert!(rep.passed());
}
