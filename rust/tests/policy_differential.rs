//! Differential pin: each canned policy interpreted by `PolicyFs` is
//! **bit-for-bit equivalent** to the frozen legacy layer it replaced —
//! read-back bytes, owner maps, `FabricCounters`, and simulated time —
//! across the synthetic, SCR and DL drivers plus a randomized
//! functional op-script. This is the safety net under the
//! models-as-data refactor: if the interpreter ever diverges from the
//! hand-written Table-6 semantics, one of these tests names the model
//! and the first diverging observable.

use pscnf::basefs::TestFabric;
use pscnf::dl::{DlDriver, DlParams};
use pscnf::fs::{legacy, FsKind, PolicyFs, WorkloadFs};
use pscnf::interval::Range;
use pscnf::scr::{ScrDriver, ScrParams};
use pscnf::sim::Cluster;
use pscnf::testkit::{self, Gen};
use pscnf::workload::{Config, SyntheticDriver};

/// The production factory (what drivers use by default).
fn policy_factory() -> impl Fn(FsKind, u32, pscnf::basefs::SharedBb) -> Box<dyn WorkloadFs> {
    |kind, id, bb| Box::new(PolicyFs::new(kind, id, bb)) as Box<dyn WorkloadFs>
}

#[test]
fn synthetic_driver_reports_identical_for_every_canned_policy() {
    for kind in FsKind::PAPER {
        for (config, phantom) in [
            (Config::CnW, true),
            (Config::SnW, true),
            (Config::CcR, true),
            (Config::CsR, true),
            (Config::CcR, false), // non-phantom: real bytes through BaseFS
        ] {
            for shards in [1usize, 4] {
                let params = config.params(2, 2, 4 << 10, 3, 7).with_files(2);
                let pf = policy_factory();
                let new = SyntheticDriver::new_with_layers(
                    &pf,
                    kind,
                    params.clone(),
                    phantom,
                    shards,
                )
                .run(Cluster::catalyst(2, 99));
                let old = SyntheticDriver::new_with_layers(
                    &legacy::build,
                    kind,
                    params,
                    phantom,
                    shards,
                )
                .run(Cluster::catalyst(2, 99));
                let tag = format!("{kind:?}/{config:?}/phantom={phantom}/shards={shards}");
                assert_eq!(new.fs, old.fs, "{tag}");
                assert_eq!(new.write_bytes, old.write_bytes, "{tag}");
                assert_eq!(new.read_bytes, old.read_bytes, "{tag}");
                assert_eq!(new.write_end, old.write_end, "{tag} write_end");
                assert_eq!(new.read_start, old.read_start, "{tag} read_start");
                assert_eq!(new.read_end, old.read_end, "{tag} read_end");
                assert_eq!(new.makespan, old.makespan, "{tag} makespan");
                assert_eq!(new.counters, old.counters, "{tag} counters");
                assert_eq!(new.sim_ops, old.sim_ops, "{tag} sim_ops");
            }
        }
    }
}

#[test]
fn scr_driver_reports_identical_for_every_canned_policy() {
    for kind in FsKind::PAPER {
        let mut params = ScrParams::with_nodes(3, 2);
        params.particles = 120_000;
        let pf = policy_factory();
        let new = ScrDriver::new_with_layers(&pf, kind, params.clone())
            .run(Cluster::catalyst(3, 5));
        let old = ScrDriver::new_with_layers(&legacy::build, kind, params)
            .run(Cluster::catalyst(3, 5));
        assert_eq!(new.ckpt_bytes, old.ckpt_bytes, "{kind:?}");
        assert_eq!(new.ckpt_end, old.ckpt_end, "{kind:?} ckpt_end");
        assert_eq!(new.restart_bytes, old.restart_bytes, "{kind:?}");
        assert_eq!(new.restart_start, old.restart_start, "{kind:?} restart_start");
        assert_eq!(new.restart_end, old.restart_end, "{kind:?} restart_end");
        assert_eq!(new.counters, old.counters, "{kind:?} counters");
        assert_eq!(new.sim_ops, old.sim_ops, "{kind:?} sim_ops");
    }
}

#[test]
fn dl_driver_reports_identical_for_every_canned_policy() {
    for kind in FsKind::PAPER {
        let params = DlParams::weak(2, 2, 2, 11);
        let pf = policy_factory();
        let new = DlDriver::new_with_layers(&pf, kind, params.clone())
            .run(Cluster::catalyst(2, 3));
        let old = DlDriver::new_with_layers(&legacy::build, kind, params)
            .run(Cluster::catalyst(2, 3));
        assert_eq!(new.read_bytes_per_epoch, old.read_bytes_per_epoch, "{kind:?}");
        assert_eq!(new.epoch_time, old.epoch_time, "{kind:?} epoch_time");
        assert_eq!(new.remote_fraction, old.remote_fraction, "{kind:?}");
        assert_eq!(new.counters, old.counters, "{kind:?} counters");
        assert_eq!(new.sim_ops, old.sim_ops, "{kind:?} sim_ops");
    }
}

/// One random op-script, applied in lockstep to a PolicyFs stack and a
/// legacy stack on separate (identical) fabrics. Every read's bytes,
/// every op's error/ok shape, and the final counters must agree; at the
/// end, the owner map visible to a fresh third client must agree too.
fn functional_lockstep(kind: FsKind, g: &mut Gen) -> Result<(), String> {
    const EXTENT: u64 = 2048;
    let nclients = 2;
    let mut fab_a = TestFabric::new(nclients + 1);
    let mut fab_b = TestFabric::new(nclients + 1);
    let mut new_fs: Vec<Box<dyn WorkloadFs>> = (0..nclients)
        .map(|i| {
            Box::new(PolicyFs::new(kind, i as u32, fab_a.bb_of(i as u32))) as Box<dyn WorkloadFs>
        })
        .collect();
    let mut old_fs: Vec<Box<dyn WorkloadFs>> = (0..nclients)
        .map(|i| legacy::build(kind, i as u32, fab_b.bb_of(i as u32)))
        .collect();
    let mut file = 0;
    for f in new_fs.iter_mut() {
        file = f.open(&mut fab_a, "/diff/script.dat");
    }
    for f in old_fs.iter_mut() {
        f.open(&mut fab_b, "/diff/script.dat");
    }

    for step in 0..g.usize(4, 24) {
        let who = g.usize(0, nclients - 1);
        let op = g.usize(0, 4);
        match op {
            0 => {
                let off = g.u64(0, EXTENT - 1);
                let len = g.u64(1, (EXTENT - off).min(120));
                let fill = (step % 251) as u8;
                let data = vec![fill; len as usize];
                let a = new_fs[who].write_at(&mut fab_a, file, off, &data);
                let b = old_fs[who].write_at(&mut fab_b, file, off, &data);
                testkit::ensure(
                    format!("{a:?}") == format!("{b:?}"),
                    format!("{kind:?} step {step}: write_at {a:?} vs {b:?}"),
                )?;
            }
            1 => {
                let off = g.u64(0, EXTENT - 1);
                let len = g.u64(1, (EXTENT - off).min(200));
                let a = new_fs[who].read_at(&mut fab_a, file, Range::at(off, len));
                let b = old_fs[who].read_at(&mut fab_b, file, Range::at(off, len));
                testkit::ensure(
                    format!("{a:?}") == format!("{b:?}"),
                    format!("{kind:?} step {step}: read_at [{off},+{len}) diverged"),
                )?;
            }
            2 => {
                let a = new_fs[who].end_write_phase(&mut fab_a, file);
                let b = old_fs[who].end_write_phase(&mut fab_b, file);
                testkit::ensure(
                    format!("{a:?}") == format!("{b:?}"),
                    format!("{kind:?} step {step}: end_write_phase diverged"),
                )?;
            }
            3 => {
                let a = new_fs[who].begin_read_phase(&mut fab_a, file);
                let b = old_fs[who].begin_read_phase(&mut fab_b, file);
                testkit::ensure(
                    format!("{a:?}") == format!("{b:?}"),
                    format!("{kind:?} step {step}: begin_read_phase diverged"),
                )?;
            }
            _ => {
                // Batched phase hooks (the sharded-attach path).
                let a = new_fs[who].end_write_phase_all(&mut fab_a, &[file]);
                let b = old_fs[who].end_write_phase_all(&mut fab_b, &[file]);
                testkit::ensure(
                    format!("{a:?}") == format!("{b:?}"),
                    format!("{kind:?} step {step}: end_write_phase_all diverged"),
                )?;
            }
        }
        testkit::ensure(
            fab_a.inner.counters == fab_b.inner.counters,
            format!(
                "{kind:?} step {step} (op {op}): counters diverged\n new: {:?}\n old: {:?}",
                fab_a.inner.counters, fab_b.inner.counters
            ),
        )?;
    }

    // Final owner maps, as seen by an uninvolved observer client.
    let mut obs_a = PolicyFs::new(FsKind::COMMIT, nclients as u32, fab_a.bb_of(nclients as u32));
    let mut obs_b = PolicyFs::new(FsKind::COMMIT, nclients as u32, fab_b.bb_of(nclients as u32));
    obs_a.open(&mut fab_a, "/diff/script.dat");
    obs_b.open(&mut fab_b, "/diff/script.dat");
    let map_a = obs_a
        .core()
        .query(&mut fab_a, file, 0, EXTENT)
        .map_err(|e| format!("observer query: {e}"))?;
    let map_b = obs_b
        .core()
        .query(&mut fab_b, file, 0, EXTENT)
        .map_err(|e| format!("observer query: {e}"))?;
    testkit::ensure(
        map_a == map_b,
        format!("{kind:?}: final owner maps diverged\n new: {map_a:?}\n old: {map_b:?}"),
    )
}

#[test]
fn functional_lockstep_posix() {
    testkit::check("lockstep posix", |g| functional_lockstep(FsKind::POSIX, g));
}

#[test]
fn functional_lockstep_commit() {
    testkit::check("lockstep commit", |g| functional_lockstep(FsKind::COMMIT, g));
}

#[test]
fn functional_lockstep_session() {
    testkit::check("lockstep session", |g| {
        functional_lockstep(FsKind::SESSION, g)
    });
}

#[test]
fn functional_lockstep_mpiio() {
    testkit::check("lockstep mpiio", |g| functional_lockstep(FsKind::MPIIO, g));
}
