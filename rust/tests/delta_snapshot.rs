//! Differential guards for the delta revalidation protocol
//! (DESIGN.md §Snapshot-Versioning): a snapshot cache brought forward by
//! applying `Response::Delta` edit logs must be indistinguishable — in
//! every read byte — from one rebuilt by a full `QueryFile` fetch.
//!
//! The trick: run the SAME write/read schedule twice. Run A keeps the
//! reader inside the server's change-log window, so its warm reopens are
//! answered with deltas. Run B interleaves `CHANGE_LOG_CAP + 1`
//! redundant republishes of a block OUTSIDE the read universe — the
//! in-universe map and bytes are untouched, but the version distance
//! evicts the reader from the window, forcing the full-snapshot
//! fallback. Bit-identical reads across A and B prove delta application
//! ≡ full refetch, and the eviction path is exercised by construction.
//!
//! Covered: every registered model (the paper four plus the built-in
//! extras) AND a model that exists only as TOML config, registered here
//! via `FsKind::register_from_ini`.

use pscnf::basefs::{DesFabric, FabricCounters, CHANGE_LOG_CAP};
use pscnf::fs::FsKind;
use pscnf::interval::Range;
use pscnf::testkit;
use pscnf::workload::build_fs;
use std::sync::OnceLock;

/// Readable byte universe; the eviction republishes land beyond it.
const UNIVERSE: u64 = 256;

/// One write: (writer index 0/1, offset, len, fill byte).
type WriteOp = (usize, u64, u64, u8);

/// A TOML-only session-equivalent model (publishes at phase end,
/// acquires a session-scoped snapshot), registered once per process.
fn conf_session_kind() -> FsKind {
    static ONCE: OnceLock<FsKind> = OnceLock::new();
    *ONCE.get_or_init(|| {
        let ini = pscnf::config::parse_ini(
            "[model.conf_delta_sess]\n\
             display = ConfDeltaSess\n\
             publication = phase_end\n\
             acquisition = session_snapshot\n",
        )
        .expect("conf model parses");
        FsKind::register_from_ini(&ini).expect("conf model registers")[0]
    })
}

/// Run the schedule on a fresh 3-client fabric (writers 0 and 1, warm
/// reader 2). Per round: every write is its own publish, then — in
/// `evict` mode — rank 0 republishes one out-of-universe block
/// `CHANGE_LOG_CAP + 1` times, then the reader reopens and reads the
/// whole universe. Returns the per-round read-backs and the counters.
fn run_schedule(
    kind: FsKind,
    rounds: &[Vec<WriteOp>],
    evict: bool,
) -> (Vec<Vec<u8>>, FabricCounters) {
    let fabric = DesFabric::new(vec![0, 0, 0]);
    let mut fs = build_fs(kind, &fabric);
    let mut fabric = fabric;
    let mut file = 0;
    for f in fs.iter_mut() {
        file = f.open(&mut fabric, "/delta/differential");
    }
    // Seed map: each writer claims 8 disjoint strided blocks, so the
    // ownership map is wide enough that a round's few edits are always
    // the cheaper answer for a within-window revalidate.
    for (w, fill) in [(0usize, 0x11u8), (1, 0x22)] {
        for b in 0..8u64 {
            let off = (b * 2 + w as u64) * 16;
            fs[w].write_at(&mut fabric, file, off, &[fill; 8]).unwrap();
        }
        fs[w].end_write_phase(&mut fabric, file).unwrap();
    }
    let mut out = Vec::new();
    for round in rounds {
        for &(who, off, len, fill) in round {
            fs[who]
                .write_at(&mut fabric, file, off, &vec![fill; len as usize])
                .unwrap();
            fs[who].end_write_phase(&mut fabric, file).unwrap();
        }
        if evict {
            // Republish an identical out-of-universe block: the read
            // range's bytes and owners never change, but every publish
            // bumps the file version, pushing the reader's cached
            // version out of the change-log window.
            for _ in 0..=CHANGE_LOG_CAP {
                fs[0]
                    .write_at(&mut fabric, file, UNIVERSE + 64, &[0x5A; 8])
                    .unwrap();
                fs[0].end_write_phase(&mut fabric, file).unwrap();
            }
        }
        fs[2].begin_read_phase(&mut fabric, file).unwrap();
        out.push(
            fs[2]
                .read_at(&mut fabric, file, Range::new(0, UNIVERSE))
                .unwrap(),
        );
        fs[2].end_write_phase(&mut fabric, file).unwrap();
    }
    (out, fabric.counters)
}

fn gen_rounds(g: &mut testkit::Gen) -> Vec<Vec<WriteOp>> {
    g.vec_of(3, |g| {
        g.vec_of(3, |g| {
            let off = g.u64(0, UNIVERSE - 9);
            let len = g.u64(1, 8);
            (g.usize(0, 1), off, len, g.u64(1, 255) as u8)
        })
    })
}

#[test]
fn delta_application_matches_full_refetch_for_every_model() {
    // Force the TOML-only model into the registry before snapshotting
    // it, so the sweep provably covers a model that exists only as data.
    let conf = conf_session_kind();
    let kinds = FsKind::registered();
    assert!(kinds.contains(&conf));
    testkit::check("delta-applied cache == full-refetch cache", |g| {
        let rounds = gen_rounds(g);
        for &kind in &kinds {
            let (delta_bytes, _) = run_schedule(kind, &rounds, false);
            let (full_bytes, full) = run_schedule(kind, &rounds, true);
            testkit::ensure(
                delta_bytes == full_bytes,
                format!("model `{}` diverged between delta and refetch", kind.name()),
            )?;
            // The eviction run can never be answered a delta: the
            // reader is always > CHANGE_LOG_CAP versions behind.
            testkit::ensure(
                full.delta_rpcs == 0,
                format!("model `{}` took a delta past the log window", kind.name()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn caching_models_ride_deltas_until_the_log_evicts() {
    // Deterministic schedule: round 0's reopen is the cold fetch; the
    // reader is then 1 and 3 publishes behind at rounds 1 and 2, so a
    // session-scoped model takes the delta path exactly there — unless
    // the eviction storm forces the snapshot fallback.
    let rounds: Vec<Vec<WriteOp>> = vec![
        vec![(0, 40, 8, 0xA1), (1, 200, 8, 0xB2)],
        vec![(1, 96, 4, 0xC3)],
        vec![(0, 44, 8, 0xD4), (0, 52, 8, 0xD5), (1, 10, 6, 0xE6)],
    ];
    for kind in [FsKind::SESSION, FsKind::MPIIO, conf_session_kind()] {
        let (a_bytes, a) = run_schedule(kind, &rounds, false);
        let (b_bytes, b) = run_schedule(kind, &rounds, true);
        assert_eq!(a_bytes, b_bytes, "{} bytes diverged", kind.name());
        assert!(
            a.delta_rpcs >= 2,
            "{}: warm stale reopens must be deltas, got {}",
            kind.name(),
            a.delta_rpcs
        );
        assert!(
            a.delta_edits >= a.delta_rpcs,
            "{}: every delta carries at least one edit",
            kind.name()
        );
        // O(changes): the deltas shipped edits for the 4 stale-making
        // publishes, never the ~18-interval map.
        assert!(
            a.delta_edits <= 8,
            "{}: delta traffic {} is not O(changes)",
            kind.name(),
            a.delta_edits
        );
        assert_eq!(b.delta_rpcs, 0, "{} evicted run took a delta", kind.name());
        assert!(
            b.revalidates > 0 && b.revalidate_hits < b.revalidates,
            "{}: evicted reopens must be revalidation misses",
            kind.name()
        );
    }
    // Commit never revalidates, so it can never be answered a delta.
    let (_, commit) = run_schedule(FsKind::COMMIT, &rounds, false);
    assert_eq!(commit.delta_rpcs, 0);
    assert_eq!(commit.revalidates, 0);
}
