//! Integration: the AOT artifact round-trip (python-lowered HLO text →
//! rust PJRT compile → execute) and the full training loop through the
//! compiled `train_step`. Skipped gracefully when `make artifacts`
//! hasn't run.

use pscnf::runtime::{Runtime, TrainState};
use pscnf::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
        return None;
    }
    match Runtime::cpu(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            // Offline builds link the xla stub; PJRT is then unavailable.
            eprintln!("SKIP: PJRT client unavailable: {e}");
            None
        }
    }
}

fn synth_batch(m: &pscnf::runtime::Manifest, seed: u64) -> (Vec<f32>, Vec<i32>) {
    // A learnable synthetic task: class = argmax over CLASSES block-sums
    // of the feature vector (deterministic function of x).
    let mut rng = Rng::seed_from_u64(seed);
    let mut x = vec![0f32; m.batch * m.feature_dim];
    let mut y = vec![0i32; m.batch];
    let block = m.feature_dim / m.classes;
    for b in 0..m.batch {
        for v in x[b * m.feature_dim..(b + 1) * m.feature_dim].iter_mut() {
            *v = (rng.next_normal() * 0.1) as f32;
        }
        let cls = rng.gen_range(0, m.classes);
        for j in 0..block {
            x[b * m.feature_dim + cls * block + j] += 2.0;
        }
        y[b] = cls as i32;
    }
    (x, y)
}

#[test]
fn artifact_loads_and_executes() {
    let Some(mut rt) = runtime_or_skip() else {
        return;
    };
    assert_eq!(rt.platform().to_lowercase().contains("cpu"), true);
    let m = rt.manifest().unwrap();
    assert_eq!(m.batch, 32);
    rt.load("train_step").unwrap();
    rt.load("predict").unwrap();
    assert_eq!(rt.loaded().len(), 2);
}

#[test]
fn train_step_reduces_loss() {
    let Some(mut rt) = runtime_or_skip() else {
        return;
    };
    let m = rt.manifest().unwrap();
    let mut state = TrainState::init(m.clone(), 42);
    let (x, y) = synth_batch(&m, 1);
    let first = state.step(&mut rt, &x, &y).unwrap();
    let mut last = first;
    for _ in 0..49 {
        last = state.step(&mut rt, &x, &y).unwrap();
    }
    assert!(last.is_finite() && first.is_finite());
    assert!(
        last < 0.5 * first,
        "loss did not decrease: {first} -> {last}"
    );
    assert_eq!(state.steps, 50);
}

#[test]
fn predict_learns_synthetic_task() {
    let Some(mut rt) = runtime_or_skip() else {
        return;
    };
    let m = rt.manifest().unwrap();
    let mut state = TrainState::init(m.clone(), 7);
    // Train over many batches of the same synthetic task.
    for round in 0..6 {
        for seed in 0..16 {
            let (x, y) = synth_batch(&m, seed);
            let _ = round;
            state.step(&mut rt, &x, &y).unwrap();
        }
    }
    // Held-out batch: accuracy must beat chance (1%) by a wide margin.
    let (x, y) = synth_batch(&m, 999);
    let ids = state.predict(&mut rt, &x).unwrap();
    let correct = ids.iter().zip(&y).filter(|(a, b)| a == b).count();
    assert!(
        correct * 100 / m.batch >= 30,
        "accuracy {}/{} too low",
        correct,
        m.batch
    );
}

#[test]
fn bad_input_shapes_error_cleanly() {
    let Some(mut rt) = runtime_or_skip() else {
        return;
    };
    let m = rt.manifest().unwrap();
    let mut state = TrainState::init(m, 1);
    let err = state.step(&mut rt, &[0.0; 8], &[0; 8]).unwrap_err();
    assert!(err.to_string().contains("batch features"));
}
