//! The machine-readable half of the bench harness: `dump → parse →
//! compare` round trips, the exact regression-gate boundary, and the
//! non-fatal handling of unknown scenarios / missing metrics — the
//! contracts the CI perf-gate job relies on.

use pscnf::bench::{compare, BenchMatrix, BenchRecord, Metric, SCHEMA_VERSION};
use pscnf::util::json::Json;

fn record(id: &str, bw: f64, rpcs: f64) -> BenchRecord {
    let mut r = BenchRecord::new(id, id.split('/').next().unwrap());
    r.param("nodes", 4u64).param("fs", "commit");
    r.metric("bw", Metric::higher(bw))
        .metric("rpcs", Metric::lower(rpcs));
    r
}

fn matrix(records: Vec<BenchRecord>) -> BenchMatrix {
    let mut m = BenchMatrix::new();
    m.records = records;
    m
}

#[test]
fn dump_parse_compare_round_trip_is_identical() {
    let m = matrix(vec![
        record("fig4/CC-R/8KiB/commit/n4", 1.25e9, 960.0),
        record("fig4/CC-R/8KiB/session/n4", 6.1e9, 130.0),
        record("smoke/scr/mpiio/n3", 3.3e8, 48.0),
    ]);
    let text = m.to_json().pretty();
    assert!(text.contains(&format!("\"schema_version\": {SCHEMA_VERSION}")));
    let back = BenchMatrix::parse(&text).unwrap();
    assert_eq!(back, m);
    // Compact form parses to the same matrix too.
    assert_eq!(BenchMatrix::parse(&m.to_json().dump()).unwrap(), m);

    // Identical matrices compare clean at any gate, including 0.
    for gate in [0.0, 15.0] {
        let rep = compare(&m, &back, gate);
        assert!(rep.passed(), "gate {gate}");
        assert!(rep.regressions().is_empty());
        assert!(rep.unknown_scenarios.is_empty());
        assert!(rep.missing_scenarios.is_empty());
        assert!(rep.missing_metrics.is_empty());
        assert_eq!(rep.deltas.len(), 6); // 3 records × 2 metrics
        assert!(rep.deltas.iter().all(|d| d.worse_pct == 0.0));
    }
}

#[test]
fn regression_at_exactly_the_gate_boundary() {
    let base = matrix(vec![record("a/b/c", 200.0, 100.0)]);

    // Higher-is-better: a drop of exactly 15% passes a 15% gate...
    let cur = matrix(vec![record("a/b/c", 170.0, 100.0)]);
    let rep = compare(&base, &cur, 15.0);
    assert!(rep.passed(), "exact-boundary drop must pass: {:?}", rep.deltas);
    let bw = rep.deltas.iter().find(|d| d.metric == "bw").unwrap();
    assert!((bw.worse_pct - 15.0).abs() < 1e-12);

    // ...and any drop strictly beyond it fails.
    let cur = matrix(vec![record("a/b/c", 169.0, 100.0)]);
    let rep = compare(&base, &cur, 15.0);
    assert!(!rep.passed());
    assert_eq!(rep.regressions().len(), 1);
    assert_eq!(rep.regressions()[0].metric, "bw");

    // Lower-is-better mirror: +15% rpcs passes, beyond fails.
    let cur = matrix(vec![record("a/b/c", 200.0, 115.0)]);
    assert!(compare(&base, &cur, 15.0).passed());
    let cur = matrix(vec![record("a/b/c", 200.0, 116.0)]);
    let rep = compare(&base, &cur, 15.0);
    assert!(!rep.passed());
    assert_eq!(rep.regressions()[0].metric, "rpcs");

    // Improvements never trip the gate, however large.
    let cur = matrix(vec![record("a/b/c", 2000.0, 1.0)]);
    assert!(compare(&base, &cur, 15.0).passed());
}

#[test]
fn unknown_scenario_and_missing_metric_are_reported_not_fatal() {
    let base = matrix(vec![
        record("a/b/c", 100.0, 10.0),
        record("retired/cell", 5.0, 5.0),
    ]);
    let mut partial = record("a/b/c", 100.0, 10.0);
    partial.metrics.remove("rpcs");
    partial.metric("new_metric", Metric::higher(1.0));
    let cur = matrix(vec![partial, record("brand/new/cell", 7.0, 7.0)]);

    let rep = compare(&base, &cur, 15.0);
    assert!(rep.passed(), "notices must not fail the gate");
    assert_eq!(rep.unknown_scenarios, vec!["brand/new/cell".to_string()]);
    assert_eq!(rep.missing_scenarios, vec!["retired/cell".to_string()]);
    // `rpcs` vanished from current, `new_metric` has no baseline.
    let mut missing = rep.missing_metrics.clone();
    missing.sort();
    assert_eq!(
        missing,
        vec![
            ("a/b/c".to_string(), "new_metric".to_string()),
            ("a/b/c".to_string(), "rpcs".to_string()),
        ]
    );
    // Only the one shared metric was actually diffed.
    assert_eq!(rep.deltas.len(), 1);
    assert_eq!(rep.deltas[0].metric, "bw");
    // The notices surface in the rendered report.
    let text = rep.render();
    assert!(text.contains("brand/new/cell"));
    assert!(text.contains("retired/cell"));
    assert!(text.contains("new_metric"));
}

#[test]
fn fully_disjoint_id_sets_fail_instead_of_passing_vacuously() {
    // A wholesale id-scheme change must not let a regression ride along
    // behind an empty comparison.
    let base = matrix(vec![record("old/scheme/a", 1.0, 1.0)]);
    let cur = matrix(vec![record("new/scheme/a", 1.0, 1.0)]);
    let rep = compare(&base, &cur, 15.0);
    assert!(rep.disjoint);
    assert!(!rep.passed());
    assert!(rep.render().contains("vacuous"));
    // Partial overlap keeps the documented non-fatal behavior.
    let cur = matrix(vec![record("old/scheme/a", 1.0, 1.0), record("new/x", 1.0, 1.0)]);
    let rep = compare(&base, &cur, 15.0);
    assert!(!rep.disjoint);
    assert!(rep.passed());
}

#[test]
fn zero_baseline_wrong_direction_is_an_unbounded_regression() {
    let base = matrix(vec![record("a/b/c", 100.0, 0.0)]);
    let cur = matrix(vec![record("a/b/c", 100.0, 3.0)]);
    let rep = compare(&base, &cur, 15.0);
    assert!(!rep.passed());
    assert!(rep.regressions()[0].worse_pct.is_infinite());
}

#[test]
fn parse_rejects_foreign_or_stale_files() {
    assert!(BenchMatrix::parse("not json").is_err());
    assert!(BenchMatrix::parse("{\"records\": []}").is_err());
    let mut j = matrix(vec![record("a/b/c", 1.0, 1.0)]).to_json();
    j.set("schema_version", SCHEMA_VERSION + 1);
    assert!(BenchMatrix::parse(&j.dump()).is_err());
}

#[test]
fn record_json_shape_is_stable() {
    // Pin the on-disk shape the CI baseline artifact depends on.
    let r = record("fig4/CC-R/8KiB/commit/n4", 2.0, 3.0);
    let j = r.to_json();
    assert_eq!(
        j.get("id").and_then(Json::as_str),
        Some("fig4/CC-R/8KiB/commit/n4")
    );
    assert_eq!(j.get("family").and_then(Json::as_str), Some("fig4"));
    let bw = j.get("metrics").and_then(|m| m.get("bw")).unwrap();
    assert_eq!(bw.get("value").and_then(Json::as_f64), Some(2.0));
    assert_eq!(bw.get("higher_is_better").and_then(Json::as_bool), Some(true));
    assert_eq!(
        j.get("params").and_then(|p| p.get("nodes")).and_then(Json::as_f64),
        Some(4.0)
    );
}
