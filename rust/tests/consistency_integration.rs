//! Cross-module integration: the SCNF guarantee itself. For random
//! *properly-synchronized* two-phase programs, every consistency layer
//! must deliver the sequentially-consistent outcome (byte-exact against
//! a write-log oracle) — §4's Properly-Synchronized SCNF System
//! definition, checked end-to-end through the real BaseFS stack.

use pscnf::basefs::TestFabric;
use pscnf::fs::{FsKind, PolicyFs, WorkloadFs};
use pscnf::interval::Range;
use pscnf::testkit::{self, Gen};

fn make_fs(kind: FsKind, id: u32, fabric: &TestFabric) -> Box<dyn WorkloadFs> {
    // The production layer: one policy interpreter for every model.
    Box::new(PolicyFs::new(kind, id, fabric.bb_of(id)))
}

/// Two-phase properly-synchronized program: disjoint per-rank writes,
/// phase sync, then reads of arbitrary ranges. The oracle is a byte map
/// of all writes.
fn scnf_roundtrip(kind: FsKind, g: &mut Gen) -> Result<(), String> {
    const FILE_SIZE: u64 = 4096;
    let nranks = g.usize(2, 4);
    let mut fabric = TestFabric::new(nranks);
    let mut fs: Vec<Box<dyn WorkloadFs>> = (0..nranks)
        .map(|r| make_fs(kind, r as u32, &fabric))
        .collect();
    let mut file = 0;
    for f in fs.iter_mut() {
        file = f.open(&mut fabric, "/scnf/prog.dat");
    }

    // Write phase: rank r owns [r*slice, (r+1)*slice) and writes random
    // sub-chunks of it (possibly overlapping its own earlier writes —
    // same-rank overlap is po-ordered, not a race).
    let slice = FILE_SIZE / nranks as u64;
    let mut oracle = vec![0u8; FILE_SIZE as usize];
    for (r, f) in fs.iter_mut().enumerate() {
        let base = r as u64 * slice;
        for _ in 0..g.usize(1, 6) {
            let off = base + g.u64(0, slice - 1);
            let len = g.u64(1, (base + slice - off).min(97));
            let fill = g.u64(1, 255) as u8;
            let data = vec![fill; len as usize];
            f.write_at(&mut fabric, file, off, &data)
                .map_err(|e| format!("write: {e}"))?;
            for b in &mut oracle[off as usize..(off + len) as usize] {
                *b = fill;
            }
        }
        f.end_write_phase(&mut fabric, file)
            .map_err(|e| format!("end_write_phase: {e}"))?;
    }

    // (Barrier happens here; TestFabric is single-threaded so ordering
    // is immediate.)

    // Read phase: every rank reads random ranges; must equal the oracle.
    for f in fs.iter_mut() {
        f.begin_read_phase(&mut fabric, file)
            .map_err(|e| format!("begin_read_phase: {e}"))?;
        for _ in 0..g.usize(1, 5) {
            let off = g.u64(0, FILE_SIZE - 1);
            let len = g.u64(1, (FILE_SIZE - off).min(301));
            let got = f
                .read_at(&mut fabric, file, Range::at(off, len))
                .map_err(|e| format!("read: {e}"))?;
            let want = &oracle[off as usize..(off + len) as usize];
            testkit::ensure(
                got == want,
                format!(
                    "{kind:?} rank {} read [{off},{}) diverged from SC oracle",
                    f.client_id(),
                    off + len
                ),
            )?;
        }
    }
    Ok(())
}

#[test]
fn scnf_guarantee_commit() {
    testkit::check("SCNF commit", |g| scnf_roundtrip(FsKind::COMMIT, g));
}

#[test]
fn scnf_guarantee_session() {
    testkit::check("SCNF session", |g| scnf_roundtrip(FsKind::SESSION, g));
}

#[test]
fn scnf_guarantee_posix() {
    testkit::check("SCNF posix", |g| scnf_roundtrip(FsKind::POSIX, g));
}

#[test]
fn scnf_guarantee_mpiio() {
    testkit::check("SCNF mpiio", |g| scnf_roundtrip(FsKind::MPIIO, g));
}

#[test]
fn scnf_guarantee_commit_strict() {
    testkit::check("SCNF commit_strict", |g| {
        scnf_roundtrip(FsKind::COMMIT_STRICT, g)
    });
}

#[test]
fn scnf_guarantee_cto() {
    // Close-to-open: the two-phase program acquires at
    // begin_read_phase, which is properly synchronized under its
    // session-shaped formal model.
    testkit::check("SCNF cto", |g| scnf_roundtrip(FsKind::CTO, g));
}

/// Eventual publication: the two-phase pattern alone is NOT properly
/// synchronized (end_write_phase publishes nothing) — but closing the
/// file is, and after the close every reader sees the SC outcome.
#[test]
fn eventual_publishes_at_close_scnf() {
    testkit::check("SCNF eventual (close)", |g| {
        const FILE_SIZE: u64 = 1024;
        let nranks = g.usize(2, 3);
        let mut fabric = TestFabric::new(nranks + 1);
        let mut writers: Vec<Box<dyn WorkloadFs>> = (0..nranks)
            .map(|r| make_fs(FsKind::EVENTUAL, r as u32, &fabric))
            .collect();
        let mut reader = make_fs(FsKind::EVENTUAL, nranks as u32, &fabric);
        let mut file = 0;
        for f in writers.iter_mut() {
            file = f.open(&mut fabric, "/scnf/eventual.dat");
        }
        reader.open(&mut fabric, "/scnf/eventual.dat");
        let slice = FILE_SIZE / nranks as u64;
        let mut oracle = vec![0u8; FILE_SIZE as usize];
        for (r, f) in writers.iter_mut().enumerate() {
            let base = r as u64 * slice;
            let len = g.u64(1, slice);
            let fill = (r + 1) as u8;
            f.write_at(&mut fabric, file, base, &vec![fill; len as usize])
                .map_err(|e| format!("write: {e}"))?;
            for b in &mut oracle[base as usize..(base + len) as usize] {
                *b = fill;
            }
            // end_write_phase is a no-op; the CLOSE publishes.
            f.end_write_phase(&mut fabric, file)
                .map_err(|e| format!("end_write_phase: {e}"))?;
            f.close(&mut fabric, file).map_err(|e| format!("close: {e}"))?;
        }
        let got = reader
            .read_at(&mut fabric, file, Range::new(0, FILE_SIZE))
            .map_err(|e| format!("read: {e}"))?;
        testkit::ensure(got == oracle, "post-close read diverged from SC oracle")
    });
}

/// Ownership takeover: when two ranks write the same range in different
/// *ordered* phases, the later attach wins for subsequent readers.
#[test]
fn later_phase_overwrites_earlier() {
    let mut fabric = TestFabric::new(3);
    let mut a = PolicyFs::new(FsKind::COMMIT, 0, fabric.bb_of(0));
    let mut b = PolicyFs::new(FsKind::COMMIT, 1, fabric.bb_of(1));
    let mut r = PolicyFs::new(FsKind::COMMIT, 2, fabric.bb_of(2));
    let f = a.open(&mut fabric, "/tko");
    b.open(&mut fabric, "/tko");
    r.open(&mut fabric, "/tko");

    a.write_at(&mut fabric, f, 0, &[1u8; 100]).unwrap();
    a.publish(&mut fabric, f).unwrap();
    // Phase 2 (ordered after phase 1): b overwrites the middle.
    b.write_at(&mut fabric, f, 25, &[2u8; 50]).unwrap();
    b.publish(&mut fabric, f).unwrap();

    let got = r.read_at(&mut fabric, f, Range::new(0, 100)).unwrap();
    assert_eq!(&got[..25], &[1u8; 25][..]);
    assert_eq!(&got[25..75], &[2u8; 50][..]);
    assert_eq!(&got[75..], &[1u8; 25][..]);
}

/// Flush + detach moves data to the underlying PFS; readers that query
/// after the detach fall through to UPFS and still see the bytes.
#[test]
fn flush_detach_upfs_fallback() {
    let mut fabric = TestFabric::new(2);
    let mut w = PolicyFs::new(FsKind::COMMIT, 0, fabric.bb_of(0));
    let mut r = PolicyFs::new(FsKind::COMMIT, 1, fabric.bb_of(1));
    let f = w.open(&mut fabric, "/persist");
    r.open(&mut fabric, "/persist");

    w.write_at(&mut fabric, f, 0, b"durable-data").unwrap();
    w.publish(&mut fabric, f).unwrap();
    w.core().flush_file(&mut fabric, f).unwrap();
    w.core().detach_file(&mut fabric, f).unwrap();

    let got = r.read_at(&mut fabric, f, Range::new(0, 12)).unwrap();
    assert_eq!(got, b"durable-data");
}

/// Failure injection: a stale session must NOT see writes published
/// after its open — and a fresh session must.
#[test]
fn session_snapshot_isolation() {
    let mut fabric = TestFabric::new(2);
    let mut w = PolicyFs::new(FsKind::SESSION, 0, fabric.bb_of(0));
    let mut r = PolicyFs::new(FsKind::SESSION, 1, fabric.bb_of(1));
    let f = w.open(&mut fabric, "/iso");
    r.open(&mut fabric, "/iso");

    w.write_at(&mut fabric, f, 0, &[9u8; 8]).unwrap();
    r.acquire(&mut fabric, f).unwrap(); // session_open before the close!
    w.publish(&mut fabric, f).unwrap(); // session_close
    let stale = r.read_at(&mut fabric, f, Range::new(0, 8)).unwrap();
    assert_eq!(stale, vec![0u8; 8], "stale session stays stale");
    r.acquire(&mut fabric, f).unwrap();
    let fresh = r.read_at(&mut fabric, f, Range::new(0, 8)).unwrap();
    assert_eq!(fresh, vec![9u8; 8]);
}

/// DES determinism at the integration level: identical seeds produce
/// identical makespans and RPC counts across full runs.
#[test]
fn des_full_run_determinism() {
    use pscnf::sim::Cluster;
    use pscnf::workload::{Config, SyntheticDriver};
    let run = || {
        let params = Config::CsR.params(4, 4, 8 << 10, 5, 77);
        SyntheticDriver::new(FsKind::SESSION, params).run(Cluster::catalyst(4, 77))
    };
    let (a, b) = (run(), run());
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.rpcs, b.rpcs);
    assert_eq!(a.read_bw(), b.read_bw());
}
