//! Crash-recovery conformance (tier-1 for the fault layer): after a
//! whole-plane metadata outage whose window ends exactly at the write
//! barrier's release, every registered model — built-ins AND models
//! that exist only as `[model.<name>]` config blocks — must honor its
//! derived [`RecoveryObligation`]:
//!
//! - `replay_to_sc`: the restarted plane replays surviving clients'
//!   attachments, so readers still observe the unique sequentially-
//!   consistent outcome (the writers' exact fill bytes) and the
//!   recovered owner map equals the healthy run's.
//! - `permitted_stale`: nothing is replayed (`replayed_intervals == 0`);
//!   a reader may observe pre-crash UPFS state (zeros) or published
//!   bytes, but never a torn block — and that is a PASS, not a failure.

use std::collections::BTreeMap;

use pscnf::basefs::DesFabric;
use pscnf::fs::{FsKind, WorkloadFs};
use pscnf::interval::Range;
use pscnf::model::RecoveryObligation;
use pscnf::sim::{Cluster, Driver, Engine, FaultAction, FaultEvent, FaultPlan, FaultTarget, Ns, SimOp};
use pscnf::workload::build_fs;

/// Register two config-only models so the conformance sweep exercises a
/// model the binary has never heard of on both sides of the obligation
/// split: `conf_repl` is a session-shaped replay-to-SC model,
/// `conf_stale` an eventual-shaped permitted-stale one. Idempotent, so
/// every test in this binary may call it.
fn register_config_models() -> (FsKind, FsKind) {
    let mut ini = BTreeMap::new();
    let mut repl = BTreeMap::new();
    repl.insert("publication".to_string(), "phase_end".to_string());
    repl.insert("acquisition".to_string(), "session_snapshot".to_string());
    ini.insert("model.conf_repl".to_string(), repl);
    let mut stale = BTreeMap::new();
    stale.insert("publication".to_string(), "on_close".to_string());
    stale.insert("acquisition".to_string(), "per_read".to_string());
    ini.insert("model.conf_stale".to_string(), stale);
    let kinds = FsKind::register_from_ini(&ini).expect("register config models");
    assert_eq!(kinds.len(), 2);
    (kinds[0], kinds[1])
}

/// Write/barrier/read workload in data mode (non-phantom): writers fill
/// disjoint blocks with distinct bytes, readers read every block after
/// the barrier, and every byte that comes back is recorded.
struct Recovery {
    fabric: DesFabric,
    fs: Vec<Box<dyn WorkloadFs>>,
    file: u64,
    step: Vec<usize>,
    m: usize,
    size: u64,
    n_writers: usize,
    collected: Vec<Vec<u8>>,
    buf: Vec<u8>,
    /// Virtual time the write barrier released; the healthy probe uses
    /// it to end the outage window exactly at the release.
    release: Ns,
}

impl Recovery {
    const NODES: usize = 2;
    const PPN: usize = 2;

    fn new(kind: FsKind, shards: usize) -> Self {
        let nranks = Self::NODES * Self::PPN;
        let fabric = DesFabric::new_uniform(Self::PPN, nranks, shards);
        let mut fs = build_fs(kind, &fabric);
        let mut fabric = fabric;
        let mut file = 0;
        for f in fs.iter_mut() {
            file = f.open(&mut fabric, "/test/recovery.dat");
        }
        for r in 0..nranks {
            while fabric.pop_cost(r as u32).is_some() {}
        }
        Self {
            fabric,
            fs,
            file,
            step: vec![0; nranks],
            m: 3,
            size: 1 << 10,
            n_writers: nranks / 2,
            collected: vec![Vec::new(); nranks],
            buf: Vec::new(),
            release: Ns::ZERO,
        }
    }

    fn fill_byte(&self, block: usize) -> u8 {
        ((block / self.m) * 16 + block % self.m + 1) as u8
    }

    fn blocks(&self) -> usize {
        self.n_writers * self.m
    }
}

impl Driver for Recovery {
    fn on_fault(&mut self, ev: &FaultEvent) {
        self.fabric.apply_fault(ev);
    }

    fn next_ops(&mut self, rank: usize, now: Ns, out: &mut Vec<SimOp>) {
        loop {
            let step = self.step[rank];
            self.step[rank] = step + 1;
            if rank < self.n_writers {
                // Writer: m writes, publish, barrier, done.
                if step < self.m {
                    let block = rank * self.m + step;
                    let payload = vec![self.fill_byte(block); self.size as usize];
                    self.fs[rank]
                        .write_at(&mut self.fabric, self.file, block as u64 * self.size, &payload)
                        .expect("recovery write");
                } else if step == self.m {
                    self.fs[rank]
                        .end_write_phase(&mut self.fabric, self.file)
                        .expect("recovery publish");
                } else if step == self.m + 1 {
                    out.push(SimOp::Barrier);
                    return;
                } else {
                    // Fence/backoff costs queued while this rank was
                    // blocked at the barrier must be priced, not lost.
                    self.fabric.drain_costs_into(rank as u32, out);
                    out.push(SimOp::Done);
                    return;
                }
            } else {
                // Reader: barrier, acquire, read every block, done.
                if step == 0 {
                    out.push(SimOp::Barrier);
                    return;
                } else if step == 1 {
                    self.release = self.release.max(now);
                    self.fs[rank]
                        .begin_read_phase(&mut self.fabric, self.file)
                        .expect("recovery acquire");
                } else if step - 2 < self.blocks() {
                    let ridx = rank - self.n_writers;
                    let block = (ridx + step - 2) % self.blocks();
                    self.buf.clear();
                    self.fs[rank]
                        .read_at_into(
                            &mut self.fabric,
                            self.file,
                            Range::at(block as u64 * self.size, self.size),
                            &mut self.buf,
                        )
                        .expect("recovery read");
                    self.collected[rank].extend_from_slice(&self.buf);
                } else {
                    self.fabric.drain_costs_into(rank as u32, out);
                    out.push(SimOp::Done);
                    return;
                }
            }
            self.fabric.drain_costs_into(rank as u32, out);
            if !out.is_empty() {
                return;
            }
        }
    }
}

fn run_recovery(kind: FsKind, shards: usize, plan: &FaultPlan, fault_aware: bool) -> Recovery {
    let mut d = Recovery::new(kind, shards);
    if fault_aware {
        d.fabric.enable_faults(kind.recovery_obligation().replays());
    }
    let nranks = Recovery::NODES * Recovery::PPN;
    let mut engine =
        Engine::uniform_with(Cluster::catalyst(Recovery::NODES, 17), Recovery::PPN, nranks);
    engine
        .run_threaded_with_plan(&mut d, 1, plan)
        .expect("recovery deadlock");
    d
}

/// Whole-plane outage ending at `release`: kill every shard one tick
/// before the barrier releases, restart every shard on the release.
fn outage(shards: usize, release: Ns) -> FaultPlan {
    let kill_at = Ns(release.0.saturating_sub(1).max(1));
    let mut plan = FaultPlan::new();
    for shard in 0..shards {
        plan.push(FaultEvent {
            at: kill_at,
            target: FaultTarget::Shard(shard),
            action: FaultAction::Kill,
        });
        plan.push(FaultEvent {
            at: release,
            target: FaultTarget::Shard(shard),
            action: FaultAction::Restart,
        });
    }
    plan
}

/// Run `kind` through the outage and assert its recovery obligation.
fn assert_conforms(kind: FsKind, shards: usize) {
    let tag = format!("{} s{shards}", kind.name());
    let healthy = run_recovery(kind, shards, &FaultPlan::new(), false);
    assert!(healthy.release > Ns::ZERO, "{tag} never released");
    let plan = outage(shards, healthy.release);
    let faulted = run_recovery(kind, shards, &plan, true);
    let obligation = kind.recovery_obligation();

    for rank in faulted.n_writers..Recovery::NODES * Recovery::PPN {
        let got = &faulted.collected[rank];
        assert_eq!(got.len(), faulted.blocks() * faulted.size as usize, "{tag} rank {rank}");
        let ridx = rank - faulted.n_writers;
        for i in 0..faulted.blocks() {
            let block = (ridx + i) % faulted.blocks();
            let fill = faulted.fill_byte(block);
            let chunk = &got[i * faulted.size as usize..(i + 1) * faulted.size as usize];
            match obligation {
                RecoveryObligation::ReplayToSc => assert!(
                    chunk.iter().all(|&b| b == fill),
                    "{tag} rank {rank} block {block}: replay-to-SC reader lost published bytes"
                ),
                RecoveryObligation::PermittedStale => assert!(
                    chunk.iter().all(|&b| b == fill || b == 0),
                    "{tag} rank {rank} block {block}: stale reads may be old or published, never torn"
                ),
            }
        }
    }

    match obligation {
        RecoveryObligation::ReplayToSc => {
            // The wipe really happened (leases were fenced), recovery
            // replayed attachments, and the plane re-converged to the
            // healthy owner map.
            assert!(faulted.fabric.counters.fenced_rpcs > 0, "{tag} fenced nothing");
            assert!(faulted.fabric.counters.replayed_intervals > 0, "{tag} replayed nothing");
            assert_eq!(
                faulted.fabric.server.total_intervals(),
                healthy.fabric.server.total_intervals(),
                "{tag} recovered owner map diverged from healthy"
            );
            assert_eq!(
                faulted.fabric.server.intervals_of(faulted.file),
                healthy.fabric.server.intervals_of(healthy.file),
                "{tag} recovered file map diverged from healthy"
            );
        }
        RecoveryObligation::PermittedStale => {
            assert_eq!(
                faulted.fabric.counters.replayed_intervals, 0,
                "{tag} permitted-stale model must not replay"
            );
        }
    }
}

#[test]
fn every_registered_model_honors_its_recovery_obligation() {
    let (conf_repl, conf_stale) = register_config_models();
    // Snapshot AFTER registering so the sweep provably covers the
    // config-only models alongside the seven built-ins.
    let kinds = FsKind::registered();
    assert!(kinds.contains(&conf_repl) && kinds.contains(&conf_stale));
    for kind in kinds {
        assert_conforms(kind, 1);
    }
}

#[test]
fn replay_models_reconverge_across_shard_counts() {
    // Multi-shard planes recover too: the outage kills and restarts
    // every shard, and replay must route each attachment back to the
    // shard that owns it.
    for kind in [FsKind::COMMIT, FsKind::SESSION, FsKind::MPIIO] {
        assert_conforms(kind, 4);
    }
}

/// The formal durability predicate (`model::stale_reads`) must agree
/// with the simulated obligation split on the same workload shape: a
/// formal trace of the Recovery driver — writers fill disjoint blocks,
/// the plane crashes at the barrier, readers then sweep every block —
/// flags every cross-rank post-crash read under a permitted-stale
/// model and nothing at all under a replay-to-SC model.
#[test]
fn stale_read_predicate_matches_obligation_split() {
    use pscnf::model::{stale_reads, StorageOp, Trace};
    let (conf_repl, conf_stale) = register_config_models();
    let (m, size, n_writers) = (3usize, 1u64 << 10, 2u32);
    let blocks = n_writers as usize * m;
    let mut t = Trace::new();
    for w in 0..n_writers {
        for i in 0..m {
            let block = w as usize * m + i;
            t.push(w, StorageOp::write(0, Range::at(block as u64 * size, size)));
        }
    }
    // The outage window ends exactly at the barrier: everything above is
    // pre-crash, every read below post-crash.
    let crash_after = t.len() - 1;
    for r in 0..2u32 {
        for i in 0..blocks {
            let block = (r as usize + i) % blocks;
            t.push(n_writers + r, StorageOp::read(0, Range::at(block as u64 * size, size)));
        }
    }

    for kind in [FsKind::EVENTUAL, FsKind::CTO, conf_stale] {
        let flagged = stale_reads(&t, crash_after, kind.recovery_obligation());
        assert_eq!(
            flagged.len(),
            2 * blocks,
            "{}: every post-crash read overlaps another rank's pre-crash write",
            kind.name()
        );
        assert!(
            flagged.iter().all(|s| s.read > crash_after && s.write <= crash_after),
            "{}: stale pairs must straddle the crash boundary",
            kind.name()
        );
    }
    for kind in [FsKind::POSIX, FsKind::COMMIT, FsKind::SESSION, FsKind::MPIIO, conf_repl] {
        assert!(
            stale_reads(&t, crash_after, kind.recovery_obligation()).is_empty(),
            "{}: replay-to-SC recovery leaves nothing stale",
            kind.name()
        );
    }
}

/// Replication-plane conformance for the checker: a `local_only`
/// model that dies between acking its last writes and shipping them to
/// a replica must have `check::lost_reads` flag exactly the reads of
/// the unreplicated blocks — and a `sync` twin of the same model shape
/// (registered purely via `[model.<name>] write_ack`) flags nothing on
/// the identical trace.
#[test]
fn local_only_ack_gap_flags_exactly_the_lost_reads() {
    use pscnf::model::{check, StorageOp, Trace, WriteAck};

    // Two config-only models identical except for the write_ack axis,
    // proving the ini key reaches the checker through FsKind.
    let mut ini = BTreeMap::new();
    for (name, ack) in [("conf_lo", "local_only"), ("conf_sync", "sync")] {
        let mut block = BTreeMap::new();
        block.insert("publication".to_string(), "on_close".to_string());
        block.insert("acquisition".to_string(), "per_read".to_string());
        block.insert("write_ack".to_string(), ack.to_string());
        ini.insert(format!("model.{name}"), block);
    }
    let kinds = FsKind::register_from_ini(&ini).expect("register ack models");
    assert_eq!(kinds.len(), 2);
    let (lo, sync) = (kinds[0], kinds[1]);
    assert_eq!(lo.write_ack(), WriteAck::LocalOnly);
    assert_eq!(sync.write_ack(), WriteAck::Sync);

    let (m, size, n_writers) = (3usize, 1u64 << 10, 2u32);
    let blocks = n_writers as usize * m;
    let mut t = Trace::new();
    for w in 0..n_writers {
        for i in 0..m {
            let block = w as usize * m + i;
            t.push(w, StorageOp::write(0, Range::at(block as u64 * size, size)));
        }
    }
    // Writer 0's blocks reached the replica; writer 1 was acked for
    // blocks 3..6 but its mirrors were still in flight at the crash.
    let replicated_through = Some(m - 1);
    let crash_after = t.len() - 1;
    for r in 0..2u32 {
        for i in 0..blocks {
            let block = (r as usize + i) % blocks;
            t.push(n_writers + r, StorageOp::read(0, Range::at(block as u64 * size, size)));
        }
    }

    let lost = check::lost_reads(
        &t,
        crash_after,
        replicated_through,
        lo.write_ack(),
        lo.recovery_obligation(),
        &[],
    );
    // Exactly the reads of the unreplicated blocks, nothing else: each
    // of the two readers sweeps blocks 3..6 once.
    assert_eq!(lost.len(), 2 * m, "{}: one lost read per reader per unreplicated block", lo.name());
    for l in &lost {
        assert!(l.read > crash_after, "lost reads are post-crash");
        assert!(l.write > replicated_through.unwrap(), "replicated writes are never lost");
        assert_eq!(l.write, (l.range.start / size) as usize, "write id is the block it filled");
    }
    let mut seen: Vec<(u32, u64)> = lost.iter().map(|l| (l.rank, l.range.start / size)).collect();
    seen.sort_unstable();
    let want: Vec<(u32, u64)> =
        (2..4u32).flat_map(|r| (m as u64..blocks as u64).map(move |b| (r, b))).collect();
    assert_eq!(seen, want, "flagged set must be exactly readers x unreplicated blocks");

    // The same trace under the sync twin is durable by construction:
    // nothing acked can sit outside a replica.
    assert!(check::lost_reads(
        &t,
        crash_after,
        replicated_through,
        sync.write_ack(),
        sync.recovery_obligation(),
        &[],
    )
    .is_empty());
    // Under replay-to-SC recovery only a *dead* writer loses bytes:
    // surviving clients re-attach their buffers at restart.
    assert!(check::lost_reads(
        &t,
        crash_after,
        replicated_through,
        lo.write_ack(),
        RecoveryObligation::ReplayToSc,
        &[],
    )
    .is_empty());
    assert_eq!(
        check::lost_reads(
            &t,
            crash_after,
            replicated_through,
            lo.write_ack(),
            RecoveryObligation::ReplayToSc,
            &[1],
        )
        .len(),
        2 * m,
        "a dead local_only writer's acked bytes are gone even under replay"
    );
}

#[test]
fn obligation_split_matches_the_model_semantics() {
    // The relaxed extensions — and only they, among the built-ins — are
    // permitted-stale; config models derive their obligation from the
    // same policy rule with no extra key.
    let (conf_repl, conf_stale) = register_config_models();
    for kind in [FsKind::CTO, FsKind::EVENTUAL, conf_stale] {
        assert_eq!(
            kind.recovery_obligation(),
            RecoveryObligation::PermittedStale,
            "{}",
            kind.name()
        );
        assert!(!kind.recovery_obligation().replays());
    }
    for kind in [
        FsKind::POSIX,
        FsKind::COMMIT,
        FsKind::SESSION,
        FsKind::MPIIO,
        FsKind::COMMIT_STRICT,
        conf_repl,
    ] {
        assert_eq!(
            kind.recovery_obligation(),
            RecoveryObligation::ReplayToSc,
            "{}",
            kind.name()
        );
    }
}
