//! Live-engine integration: real threads, real channels, real bytes —
//! the paper's two-phase workloads with actual concurrency, verified
//! byte-exact, plus failure injection.

use pscnf::coordinator::LiveCluster;
use pscnf::fs::{FsKind, PolicyFs, WorkloadFs};
use pscnf::interval::Range;
use std::sync::{Arc, Barrier};

/// Deterministic pattern for (rank, offset).
fn fill_byte(rank: usize, block: u64) -> u8 {
    (rank as u64 * 31 + block * 7 + 1) as u8
}

/// CC-R on live threads: half the ranks write, a barrier, then the other
/// half read back byte-exact.
fn live_ccr(kind: FsKind, nranks: usize, blocks_per_writer: u64, block: u64) {
    let writers = nranks / 2;
    let mut cluster = LiveCluster::new(nranks, 4);
    let fabrics = cluster.take_fabrics();
    let barrier = Arc::new(Barrier::new(nranks));

    let mut handles = Vec::new();
    for (rank, mut fabric) in fabrics.into_iter().enumerate() {
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut fs: Box<dyn WorkloadFs> =
                Box::new(PolicyFs::new(kind, rank as u32, fabric.bb_of(rank as u32)));
            let file = fs.open(&mut fabric, "/live/ccr.dat");
            if rank < writers {
                for b in 0..blocks_per_writer {
                    let off = (rank as u64 * blocks_per_writer + b) * block;
                    let data = vec![fill_byte(rank, b); block as usize];
                    fs.write_at(&mut fabric, file, off, &data).unwrap();
                }
                fs.end_write_phase(&mut fabric, file).unwrap();
                barrier.wait();
            } else {
                barrier.wait();
                fs.begin_read_phase(&mut fabric, file).unwrap();
                // Reader j reads writer j's region (CC-R mapping).
                let peer = rank - writers;
                for b in 0..blocks_per_writer {
                    let off = (peer as u64 * blocks_per_writer + b) * block;
                    let got = fs
                        .read_at(&mut fabric, file, Range::at(off, block))
                        .unwrap();
                    assert!(
                        got.iter().all(|&x| x == fill_byte(peer, b)),
                        "rank {rank} read wrong bytes at block {b} of writer {peer}"
                    );
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    cluster.shutdown();
}

#[test]
fn live_ccr_session_byte_exact() {
    live_ccr(FsKind::SESSION, 8, 6, 4096);
}

#[test]
fn live_ccr_commit_byte_exact() {
    live_ccr(FsKind::COMMIT, 8, 6, 4096);
}

/// Strided reads (CS-R): every reader touches every writer's data.
#[test]
fn live_csr_session_byte_exact() {
    const NR: usize = 6;
    const BLOCK: u64 = 2048;
    const M: u64 = 4;
    let writers = NR / 2;
    let readers = NR - writers;
    let mut cluster = LiveCluster::new(NR, 3);
    let fabrics = cluster.take_fabrics();
    let barrier = Arc::new(Barrier::new(NR));
    let mut handles = Vec::new();
    for (rank, mut fabric) in fabrics.into_iter().enumerate() {
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut fs = PolicyFs::new(FsKind::SESSION, rank as u32, fabric.bb_of(rank as u32));
            let file = WorkloadFs::open(&mut fs, &mut fabric, "/live/csr.dat");
            if rank < writers {
                for b in 0..M {
                    let off = (rank as u64 * M + b) * BLOCK;
                    let data = vec![fill_byte(rank, b); BLOCK as usize];
                    fs.write_at(&mut fabric, file, off, &data).unwrap();
                }
                fs.publish(&mut fabric, file).unwrap(); // session_close
                barrier.wait();
            } else {
                barrier.wait();
                fs.acquire(&mut fabric, file).unwrap(); // session_open
                let j = (rank - writers) as u64;
                let total_blocks = writers as u64 * M;
                let mut i = j;
                while i < total_blocks {
                    let off = i * BLOCK;
                    let got = fs.read_at(&mut fabric, file, Range::at(off, BLOCK)).unwrap();
                    let owner = (i / M) as usize;
                    let blk = i % M;
                    assert!(
                        got.iter().all(|&x| x == fill_byte(owner, blk)),
                        "strided read mismatch at block {i}"
                    );
                    i += readers as u64;
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    cluster.shutdown();
}

/// Failure injection: concurrent readers of a range the writer detaches
/// mid-run either see the data (fetch won the race) or a clean NotOwned
/// error — never garbage and never a hang.
#[test]
fn live_detach_race_is_clean() {
    let mut cluster = LiveCluster::new(2, 2);
    let mut fabrics = cluster.take_fabrics();
    let mut reader_fabric = fabrics.pop().unwrap();
    let mut writer_fabric = fabrics.pop().unwrap();

    let mut w = PolicyFs::new(FsKind::COMMIT, 0, writer_fabric.bb_of(0));
    let file = WorkloadFs::open(&mut w, &mut writer_fabric, "/live/detach.dat");
    w.write_at(&mut writer_fabric, file, 0, &[7u8; 65536]).unwrap();
    w.publish(&mut writer_fabric, file).unwrap(); // commit

    let reader = std::thread::spawn(move || {
        let mut r = PolicyFs::new(FsKind::COMMIT, 1, reader_fabric.bb_of(1));
        let file = WorkloadFs::open(&mut r, &mut reader_fabric, "/live/detach.dat");
        let mut ok = 0;
        let mut not_owned = 0;
        for _ in 0..200 {
            match r.read_at(&mut reader_fabric, file, Range::new(0, 65536)) {
                Ok(data) => {
                    // Data present: must be entirely the written pattern
                    // or entirely zeros (post-detach UPFS fallback).
                    let first = data[0];
                    assert!(first == 7 || first == 0);
                    assert!(data.iter().all(|&b| b == first), "torn read");
                    ok += 1;
                }
                Err(_) => not_owned += 1,
            }
        }
        (ok, not_owned)
    });

    // Let the reader make progress, then detach (no flush: data vanishes).
    std::thread::sleep(std::time::Duration::from_millis(5));
    w.core().detach_file(&mut writer_fabric, file).unwrap();

    let (ok, not_owned) = reader.join().unwrap();
    assert_eq!(ok + not_owned, 200);
    cluster.shutdown();
}
