//! Parallel event-loop equivalence (tier-1 for the windowed engine):
//! for every registered consistency model and every Table-8 config, the
//! partitioned loop at P ∈ {2, 8} must reproduce the serial loop's
//! reports BYTE-IDENTICALLY — virtual times, full fabric counters, DES
//! op counts, and (via a data-mode read-back driver) the actual bytes
//! readers observe. Also pins the streaming plan generators against
//! their materialized counterparts, since the lazy/streamed large-scale
//! path depends on them agreeing exactly.

use pscnf::basefs::DesFabric;
use pscnf::dl::{DlDriver, DlParams};
use pscnf::fs::{FsKind, WorkloadFs};
use pscnf::interval::Range;
use pscnf::model::WriteAck;
use pscnf::scr::{ScrDriver, ScrParams};
use pscnf::sim::{Cluster, Driver, Engine, FaultEvent, FaultPlan, Ns, ReplicaParams, SimOp};
use pscnf::workload::{build_fs, Config, Pattern, SyntheticDriver};

const CONFIGS: [Config; 4] = [Config::CnW, Config::SnW, Config::CcR, Config::CsR];

#[test]
fn synthetic_reports_identical_for_p_1_2_8_all_models() {
    for fs in FsKind::registered() {
        for config in CONFIGS {
            let params = |seed| config.params(2, 2, 8 << 10, 3, seed);
            let base = SyntheticDriver::new(fs, params(7)).run(Cluster::catalyst(2, 9));
            for threads in [2usize, 8] {
                let got = SyntheticDriver::new(fs, params(7))
                    .run_with_threads(Cluster::catalyst(2, 9), threads);
                let tag = format!("{}/{} P={threads}", fs.name(), config.name());
                assert_eq!(got.makespan, base.makespan, "{tag} makespan");
                assert_eq!(got.write_end, base.write_end, "{tag} write_end");
                assert_eq!(got.read_start, base.read_start, "{tag} read_start");
                assert_eq!(got.read_end, base.read_end, "{tag} read_end");
                assert_eq!(got.counters, base.counters, "{tag} counters");
                assert_eq!(got.rpcs, base.rpcs, "{tag} rpcs");
                assert_eq!(got.sim_ops, base.sim_ops, "{tag} sim_ops");
            }
        }
    }
}

#[test]
fn scr_and_dl_reports_identical_for_p_1_2_8() {
    for fs in [FsKind::COMMIT, FsKind::SESSION] {
        let scr = |threads: usize| {
            let mut p = ScrParams::with_nodes(3, 2);
            p.particles = 240_000;
            ScrDriver::new(fs, p).run_with_threads(Cluster::catalyst(3, 5), threads)
        };
        let base = scr(1);
        for threads in [2usize, 8] {
            let got = scr(threads);
            assert_eq!(got.ckpt_end, base.ckpt_end, "scr {} P={threads}", fs.name());
            assert_eq!(got.restart_start, base.restart_start);
            assert_eq!(got.restart_end, base.restart_end);
            assert_eq!(got.counters, base.counters);
            assert_eq!(got.sim_ops, base.sim_ops);
        }

        let dl = |threads: usize| {
            let mut p = DlParams::weak(2, 2, 2, 7);
            p.aggregate = true;
            DlDriver::new(fs, p).run_with_threads(Cluster::catalyst(2, 5), threads)
        };
        let base = dl(1);
        for threads in [2usize, 8] {
            let got = dl(threads);
            assert_eq!(got.epoch_time, base.epoch_time, "dl {} P={threads}", fs.name());
            assert_eq!(got.counters, base.counters);
            assert_eq!(got.sim_ops, base.sim_ops);
            assert_eq!(got.remote_fraction, base.remote_fraction);
        }
    }
}

#[test]
fn streamed_lazy_run_matches_eager_serial() {
    // The O(active-rank) path (lazy layers + on-demand plans) must not
    // perturb a single metric. Commit and session are the models the
    // large-scale families run (acquire-on-open models stay eager).
    for fs in [FsKind::COMMIT, FsKind::SESSION] {
        for config in CONFIGS {
            let params = |seed| config.params(2, 2, 8 << 10, 4, seed);
            let base = SyntheticDriver::new_sharded(fs, params(11), 1).run(Cluster::catalyst(2, 3));
            let lazy = SyntheticDriver::new_lazy(fs, params(11), 1)
                .run_with_threads(Cluster::catalyst(2, 3), 8);
            let tag = format!("{}/{}", fs.name(), config.name());
            assert_eq!(lazy.makespan, base.makespan, "{tag} makespan");
            assert_eq!(lazy.write_end, base.write_end, "{tag} write_end");
            assert_eq!(lazy.read_end, base.read_end, "{tag} read_end");
            assert_eq!(lazy.counters, base.counters, "{tag} counters");
            assert_eq!(lazy.sim_ops, base.sim_ops, "{tag} sim_ops");
        }
    }
}

/// Data-mode (non-phantom) driver that records every byte its readers
/// get back, so the parallel loop's equivalence is checked on DATA, not
/// just on timings: writers fill disjoint blocks with distinct fill
/// bytes, readers read all blocks after the barrier.
struct ReadBack {
    fabric: DesFabric,
    fs: Vec<Box<dyn WorkloadFs>>,
    file: u64,
    step: Vec<usize>,
    m: usize,
    size: u64,
    n_writers: usize,
    collected: Vec<Vec<u8>>,
    buf: Vec<u8>,
    /// Virtual time the write barrier released (the healthy probe uses
    /// it to place a fault window that ends exactly at the release).
    release: Ns,
}

impl ReadBack {
    const NODES: usize = 2;
    const PPN: usize = 2;

    fn new(kind: FsKind, m: usize) -> Self {
        let nranks = Self::NODES * Self::PPN;
        let fabric = DesFabric::new_uniform(Self::PPN, nranks, 1);
        let mut fs = build_fs(kind, &fabric);
        let mut fabric = fabric;
        let mut file = 0;
        for f in fs.iter_mut() {
            file = f.open(&mut fabric, "/test/readback.dat");
        }
        for r in 0..nranks {
            while fabric.pop_cost(r as u32).is_some() {}
        }
        Self {
            fabric,
            fs,
            file,
            step: vec![0; nranks],
            m,
            size: 1 << 10,
            n_writers: nranks / 2,
            collected: vec![Vec::new(); nranks],
            buf: Vec::new(),
            release: Ns::ZERO,
        }
    }

    /// Switch the fabric fault-aware (`replay` = the model's
    /// replay-to-SC obligation) so a scheduled shard outage fences
    /// leases and recovers state instead of being a silent wipe.
    fn with_faults(mut self, replay: bool) -> Self {
        self.fabric.enable_faults(replay);
        self
    }

    fn fill_byte(&self, block: usize) -> u8 {
        ((block / self.m) * 16 + block % self.m + 1) as u8
    }

    fn blocks(&self) -> usize {
        self.n_writers * self.m
    }
}

impl Driver for ReadBack {
    fn on_fault(&mut self, ev: &FaultEvent) {
        self.fabric.apply_fault(ev);
    }

    fn next_ops(&mut self, rank: usize, now: Ns, out: &mut Vec<SimOp>) {
        // Advance the durability plane's clock at the serialized commit
        // point (a no-op unless a test enabled replication), mirroring
        // what the production drivers do for thread-count invariance.
        self.fabric.set_now(now);
        loop {
            let step = self.step[rank];
            self.step[rank] = step + 1;
            if rank < self.n_writers {
                // Writer: m writes, publish, barrier, done.
                if step < self.m {
                    let block = rank * self.m + step;
                    let payload = vec![self.fill_byte(block); self.size as usize];
                    self.fs[rank]
                        .write_at(&mut self.fabric, self.file, block as u64 * self.size, &payload)
                        .expect("read-back write");
                } else if step == self.m {
                    self.fs[rank]
                        .end_write_phase(&mut self.fabric, self.file)
                        .expect("read-back publish");
                } else if step == self.m + 1 {
                    out.push(SimOp::Barrier);
                    return;
                } else {
                    // Recovery costs queued while this rank was blocked
                    // at the barrier must be priced, not dropped.
                    self.fabric.drain_costs_into(rank as u32, out);
                    out.push(SimOp::Done);
                    return;
                }
            } else {
                // Reader: barrier, acquire, read every block, done.
                if step == 0 {
                    out.push(SimOp::Barrier);
                    return;
                } else if step == 1 {
                    self.release = self.release.max(now);
                    self.fs[rank]
                        .begin_read_phase(&mut self.fabric, self.file)
                        .expect("read-back acquire");
                } else if step - 2 < self.blocks() {
                    let ridx = rank - self.n_writers;
                    let block = (ridx + step - 2) % self.blocks();
                    self.buf.clear();
                    self.fs[rank]
                        .read_at_into(
                            &mut self.fabric,
                            self.file,
                            Range::at(block as u64 * self.size, self.size),
                            &mut self.buf,
                        )
                        .expect("read-back read");
                    self.collected[rank].extend_from_slice(&self.buf);
                } else {
                    self.fabric.drain_costs_into(rank as u32, out);
                    out.push(SimOp::Done);
                    return;
                }
            }
            self.fabric.drain_costs_into(rank as u32, out);
            if !out.is_empty() {
                return;
            }
        }
    }
}

fn run_readback(kind: FsKind, threads: usize) -> (Vec<Vec<u8>>, u64) {
    let (d, ops) = run_readback_plan(kind, threads, &FaultPlan::new(), false);
    (d.collected, ops)
}

/// Run the read-back driver under a fault plan; `fault_aware` switches
/// the fabric into lease mode with the model's own recovery obligation.
/// Returns the whole driver so callers can inspect the post-run owner
/// map and counters, not just the collected bytes.
fn run_readback_plan(
    kind: FsKind,
    threads: usize,
    plan: &FaultPlan,
    fault_aware: bool,
) -> (ReadBack, u64) {
    let mut d = ReadBack::new(kind, 3);
    if fault_aware {
        d = d.with_faults(kind.recovery_obligation().replays());
    }
    let nranks = ReadBack::NODES * ReadBack::PPN;
    let mut engine = Engine::uniform_with(
        Cluster::catalyst(ReadBack::NODES, 17),
        ReadBack::PPN,
        nranks,
    );
    let stats = engine
        .run_threaded_with_plan(&mut d, threads, plan)
        .expect("read-back deadlock");
    let ops = stats.ops_executed;
    (d, ops)
}

/// Like [`run_readback_plan`] with the durability plane enabled: a
/// 2-replica set per shard, the given ack mode resolved to its acked
/// tier count, fault-aware fabric.
fn run_readback_repl(
    kind: FsKind,
    threads: usize,
    plan: &FaultPlan,
    params: ReplicaParams,
    ack: WriteAck,
) -> (ReadBack, u64) {
    let mut d = ReadBack::new(kind, 3);
    d.fabric
        .enable_replication(params.clone(), ack.acked_replicas(params.replicas));
    d = d.with_faults(kind.recovery_obligation().replays());
    let nranks = ReadBack::NODES * ReadBack::PPN;
    let mut engine = Engine::uniform_with(
        Cluster::catalyst(ReadBack::NODES, 17),
        ReadBack::PPN,
        nranks,
    );
    let stats = engine
        .run_threaded_with_plan(&mut d, threads, plan)
        .expect("replicated read-back deadlock");
    let ops = stats.ops_executed;
    (d, ops)
}

#[test]
fn replicated_faulted_runs_identical_for_p_1_4() {
    // The durability plane under the parallel loop: a whole-shard kill
    // one tick before the write barrier releases, restart 500µs after —
    // so the read phase opens against a dead primary and fails over to
    // replicas. For EVERY ack mode the P=4 run must reproduce the
    // serial run byte-for-byte: collected reader bytes, DES op counts,
    // fabric counters (including lost_bytes/failover_reads), and the
    // post-recovery owner map.
    for kind in [FsKind::COMMIT, FsKind::SESSION] {
        for ack in [WriteAck::Sync, WriteAck::LocalOnly] {
            // The healthy probe runs the SAME replication config, so
            // sync's ack latency is inside the release time the fault
            // window is placed against.
            let (probe, _) = run_readback_repl(
                kind,
                1,
                &FaultPlan::new(),
                ReplicaParams::far(),
                ack,
            );
            let release = probe.release;
            assert!(release > Ns::ZERO, "{} never released", kind.name());
            let plan = FaultPlan::shard_outage(0, release - Ns(1), release + Ns(500_000));
            let (base, base_ops) =
                run_readback_repl(kind, 1, &plan, ReplicaParams::far(), ack);
            let tag = format!("{}/{}", kind.name(), ack.name());
            // Degraded reads really were served by the replica plane.
            assert!(base.fabric.counters.failover_reads > 0, "{tag} no failover");
            if ack == WriteAck::Sync {
                // Sync acked every replica before the barrier: the kill
                // can destroy nothing, and every reader still observes
                // the writers' fill bytes.
                assert_eq!(base.fabric.counters.lost_bytes, 0, "{tag}");
                for rank in base.n_writers..ReadBack::NODES * ReadBack::PPN {
                    let got = &base.collected[rank];
                    assert_eq!(got.len(), base.blocks() * base.size as usize, "{tag}");
                    let ridx = rank - base.n_writers;
                    for i in 0..base.blocks() {
                        let block = (ridx + i) % base.blocks();
                        let chunk =
                            &got[i * base.size as usize..(i + 1) * base.size as usize];
                        assert!(
                            chunk.iter().all(|&b| b == base.fill_byte(block)),
                            "{tag} rank {rank} block {block} lost despite sync ack"
                        );
                    }
                }
            } else {
                // local_only acked the publishes while their far-tier
                // mirrors were still in flight; the kill destroys them.
                assert!(base.fabric.counters.lost_bytes > 0, "{tag} lost nothing");
            }
            for threads in [4usize] {
                let (got, got_ops) =
                    run_readback_repl(kind, threads, &plan, ReplicaParams::far(), ack);
                let tag = format!("{tag} P={threads}");
                assert_eq!(got.collected, base.collected, "{tag} bytes");
                assert_eq!(got_ops, base_ops, "{tag} ops");
                assert_eq!(got.fabric.counters, base.fabric.counters, "{tag} counters");
                assert_eq!(
                    got.fabric.server.intervals_of(got.file),
                    base.fabric.server.intervals_of(base.file),
                    "{tag} owner map"
                );
            }
        }
    }
}

#[test]
fn read_back_bytes_identical_across_thread_counts() {
    for kind in [FsKind::COMMIT, FsKind::SESSION] {
        let (base, base_ops) = run_readback(kind, 1);
        // The serial run itself must observe the writers' fill bytes.
        let probe = ReadBack::new(kind, 3);
        for rank in probe.n_writers..ReadBack::NODES * ReadBack::PPN {
            let got = &base[rank];
            assert_eq!(got.len(), probe.blocks() * probe.size as usize);
            let ridx = rank - probe.n_writers;
            for i in 0..probe.blocks() {
                let block = (ridx + i) % probe.blocks();
                let chunk = &got[i * probe.size as usize..(i + 1) * probe.size as usize];
                assert!(
                    chunk.iter().all(|&b| b == probe.fill_byte(block)),
                    "{} rank {rank} block {block} corrupted",
                    kind.name()
                );
            }
        }
        for threads in [2usize, 8] {
            let (got, got_ops) = run_readback(kind, threads);
            assert_eq!(got, base, "{} P={threads} read-back bytes", kind.name());
            assert_eq!(got_ops, base_ops, "{} P={threads} ops", kind.name());
        }
    }
}

#[test]
fn faulted_runs_identical_for_p_1_4_with_same_owner_map() {
    // Same seed + same FaultPlan ⇒ byte-identical read-back bytes, DES
    // op counts, fabric counters AND post-recovery owner maps for any
    // engine thread count: faults apply at the serialized commit point
    // both loops share. The outage window ends at the write barrier's
    // release, so for replay-to-SC models the readers still observe the
    // unique SC outcome and the recovered map matches the healthy one.
    for kind in [FsKind::COMMIT, FsKind::SESSION] {
        let (probe, _) = run_readback_plan(kind, 1, &FaultPlan::new(), false);
        let release = probe.release;
        assert!(release > Ns::ZERO, "{} never released", kind.name());
        let plan = FaultPlan::shard_outage(0, release - Ns(1), release);
        let (base, base_ops) = run_readback_plan(kind, 1, &plan, true);
        for rank in base.n_writers..ReadBack::NODES * ReadBack::PPN {
            let got = &base.collected[rank];
            assert_eq!(got.len(), base.blocks() * base.size as usize);
            let ridx = rank - base.n_writers;
            for i in 0..base.blocks() {
                let block = (ridx + i) % base.blocks();
                let chunk = &got[i * base.size as usize..(i + 1) * base.size as usize];
                assert!(
                    chunk.iter().all(|&b| b == base.fill_byte(block)),
                    "{} rank {rank} block {block} lost to the outage",
                    kind.name()
                );
            }
        }
        // The wipe really happened (fences + replay were priced) and the
        // replayed map re-converged to the healthy one.
        assert!(base.fabric.counters.fenced_rpcs > 0, "{}", kind.name());
        assert!(base.fabric.counters.replayed_intervals > 0, "{}", kind.name());
        assert_eq!(
            base.fabric.server.total_intervals(),
            probe.fabric.server.total_intervals(),
            "{} owner map diverged from healthy",
            kind.name()
        );
        for threads in [4usize] {
            let (got, got_ops) = run_readback_plan(kind, threads, &plan, true);
            let tag = format!("{} P={threads}", kind.name());
            assert_eq!(got.collected, base.collected, "{tag} bytes");
            assert_eq!(got_ops, base_ops, "{tag} ops");
            assert_eq!(got.fabric.counters, base.fabric.counters, "{tag} counters");
            assert_eq!(
                got.fabric.server.total_intervals(),
                base.fabric.server.total_intervals(),
                "{tag} owner-map size"
            );
            assert_eq!(
                got.fabric.server.intervals_of(got.file),
                base.fabric.server.intervals_of(base.file),
                "{tag} owner map"
            );
        }
    }
}

#[test]
fn streaming_plans_match_materialized_plans() {
    for config in CONFIGS {
        for read_override in [None, Some(Pattern::Random)] {
            let mut p = config.params(4, 3, 8 << 10, 5, 13);
            if let (Some(over), Some(_)) = (read_override, p.read_pattern) {
                p.read_pattern = Some(over);
            }
            let shuffle = p.write_shuffle();
            for w in 0..p.n_writers() {
                let plan = p.write_offsets(w);
                for (i, &off) in plan.iter().enumerate() {
                    assert_eq!(
                        p.write_offset_at(&shuffle, w, i),
                        off,
                        "{} writer {w} op {i}",
                        config.name()
                    );
                }
            }
            if p.read_pattern.is_some() {
                for r in 0..p.n_readers() {
                    let plan = p.read_offsets(r);
                    let mut rng = p.read_rng(r);
                    for (i, &off) in plan.iter().enumerate() {
                        assert_eq!(
                            p.read_offset_at(r, i, &mut rng),
                            off,
                            "{} reader {r} op {i} ({read_override:?})",
                            config.name()
                        );
                    }
                }
            }
        }
    }
}
