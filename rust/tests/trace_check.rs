//! End-to-end guarantees for the `pscnf check` pipeline: the indexed
//! frontier detector must be verdict-identical to the frozen all-pairs
//! reference on *randomized* traces for every registered model, the
//! JSONL persistence layer must round-trip recorded traces exactly
//! (and reject foreign schemas), and the detector must stay practical
//! on traces four orders of magnitude past the litmus sizes.

use pscnf::fs::FsKind;
use pscnf::interval::Range;
use pscnf::model::check::{check, detect_indexed, TraceIndex};
use pscnf::model::{detect, detect_with, persist, StorageOp, SyncKind, Trace};
use pscnf::testkit::{self, Gen};
use pscnf::workload::Config;

/// A random formal trace: 2-4 ranks, 1-2 files, a mix of reads, writes
/// and sync ops over a small byte space (so overlaps are common), plus
/// random forward so-edges (always old → new in push order, so the
/// happens-before relation stays acyclic by construction).
fn random_trace(g: &mut Gen) -> Trace {
    let nranks = g.usize(2, 4) as u32;
    let nfiles = g.usize(1, 2) as u32;
    let syncs = [
        SyncKind::Commit,
        SyncKind::SessionOpen,
        SyncKind::SessionClose,
        SyncKind::MpiFileOpen,
        SyncKind::MpiFileClose,
        SyncKind::MpiFileSync,
    ];
    let mut t = Trace::new();
    let mut ids = Vec::new();
    let ops = g.usize(2, (4 * g.size()).max(8));
    for _ in 0..ops {
        let rank = g.u64(0, (nranks - 1) as u64) as u32;
        let file = g.u64(0, (nfiles - 1) as u64) as u32;
        let op = match g.usize(0, 3) {
            0 => StorageOp::sync(*g.choose(&syncs), file),
            1 => StorageOp::read(file, Range::at(g.u64(0, 48), g.u64(1, 16))),
            _ => StorageOp::write(file, Range::at(g.u64(0, 48), g.u64(1, 16))),
        };
        ids.push(t.push(rank, op));
    }
    // Forward-only cross-rank edges keep hb a DAG.
    for _ in 0..g.usize(0, ops / 2) {
        let a = g.usize(0, ids.len() - 2);
        let b = g.usize(a + 1, ids.len() - 1);
        t.add_so(ids[a], ids[b]);
    }
    t
}

/// Property: on arbitrary traces the interval-indexed frontier detector
/// and the frozen all-pairs oracle agree on the *entire* report (total
/// race count, deduped representatives, synchronized-pair count) for
/// every model in the registry — builtin and paper models alike.
#[test]
fn indexed_detector_matches_reference_on_random_traces() {
    testkit::check("detect_indexed == detect (all models)", |g| {
        let t = random_trace(g);
        let hb = t
            .happens_before()
            .map_err(|e| format!("random trace must be acyclic: {e}"))?;
        let index = TraceIndex::build(&t);
        for kind in FsKind::registered() {
            let model = kind.model();
            let reference = detect_with(&t, &hb, &model);
            let fast = detect_indexed(&t, &hb, &index, &model);
            testkit::ensure(
                reference == fast,
                format!(
                    "verdict diverged under {} ({}): reference {} race(s) vs indexed {}",
                    kind.name(),
                    model.name,
                    reference.total_races,
                    fast.total_races
                ),
            )?;
        }
        Ok(())
    });
}

/// Property: serializing any random trace to JSONL and parsing it back
/// reproduces the events, the so-edges, and therefore every model's
/// race verdict bit-for-bit.
#[test]
fn jsonl_round_trip_preserves_trace_and_verdicts() {
    testkit::check("persist round-trip", |g| {
        let t = random_trace(g);
        let back = persist::from_jsonl(&persist::to_jsonl(&t))
            .map_err(|e| format!("round-trip parse failed: {e}"))?;
        testkit::ensure(back.events() == t.events(), "events diverged")?;
        testkit::ensure(back.so_edges() == t.so_edges(), "so edges diverged")?;
        for kind in FsKind::registered() {
            let model = kind.model();
            let a = detect(&t, &model).map_err(|e| e.to_string())?;
            let b = detect(&back, &model).map_err(|e| e.to_string())?;
            testkit::ensure(
                a == b,
                format!("verdict diverged after round-trip under {}", kind.name()),
            )?;
        }
        Ok(())
    });
}

/// Recorded synthetic traces survive the full file path (save → load)
/// and keep their verdicts: the two-phase CC-R pattern is race-free
/// under commit consistency but racy under eventual consistency.
#[test]
fn recorded_trace_survives_save_load_with_verdicts_intact() {
    let params = Config::CcR.params(2, 2, 1 << 10, 3, 42);
    let trace = pscnf::trace::record_synthetic(&params, FsKind::COMMIT, 2);
    assert!(!trace.events().is_empty(), "recording produced an empty trace");

    let path = std::env::temp_dir().join(format!(
        "pscnf_trace_check_{}.trace.jsonl",
        std::process::id()
    ));
    persist::save(&trace, &path).expect("save recorded trace");
    let loaded = persist::load(&path).expect("load recorded trace");
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.events(), trace.events());
    assert_eq!(loaded.so_edges(), trace.so_edges());
    let commit = check(&loaded, &FsKind::COMMIT.model()).unwrap();
    assert!(
        commit.race_free(),
        "two-phase commit workload must certify under commit: {} race(s)",
        commit.total_races
    );
    let eventual = check(&loaded, &FsKind::EVENTUAL.model()).unwrap();
    assert!(
        !eventual.race_free(),
        "eventual consistency cannot certify the cross-rank read-after-write"
    );
}

/// A trace written by a future (or foreign) tool is rejected up front
/// with a schema diagnostic instead of a garbled parse.
#[test]
fn foreign_schema_is_rejected() {
    let t = {
        let mut t = Trace::new();
        t.push(0, StorageOp::write(0, Range::new(0, 8)));
        t
    };
    let good = persist::to_jsonl(&t);
    let bad = good.replacen("\"schema\":1", "\"schema\":99", 1);
    assert_ne!(good, bad, "header tamper must change the text");
    let err = persist::from_jsonl(&bad).unwrap_err();
    assert!(err.contains("schema"), "error must name the schema: {err}");
}

/// Scalability: 10^4 mostly-disjoint data ops (the regime the old
/// all-pairs detector handled quadratically). The interval sweep only
/// visits true overlaps, so this must complete comfortably inside a
/// unit-test budget while still agreeing with the reference oracle on
/// the exact race census.
#[test]
fn frontier_detector_handles_ten_thousand_ops() {
    let mut t = Trace::new();
    // 8 ranks × 1250 strided writes each: disjoint within a rank,
    // every block contended by all 8 ranks across ranks.
    for i in 0..1250u64 {
        for rank in 0..8u32 {
            t.push(rank, StorageOp::write(0, Range::at(i * 8, 8)));
        }
    }
    assert_eq!(t.len(), 10_000);
    let model = FsKind::POSIX.model();
    let rep = check(&t, &model).unwrap();
    assert!(!rep.race_free());
    // Each of the 1250 blocks has C(8,2)=28 unordered conflicting pairs —
    // an analytic census the all-pairs oracle would spend ~5·10^7 pair
    // probes to confirm (the randomized differential test above covers
    // oracle agreement; here the expected count is known in closed form).
    assert_eq!(rep.total_races, 1250 * 28);
}
