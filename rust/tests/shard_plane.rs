//! Sharding correctness anchors: a 1-shard and an 8-shard
//! `MetadataPlane` must produce IDENTICAL responses for identical
//! request traces (sharding changes performance, never semantics), and
//! the routing invariants every layer relies on.

use pscnf::basefs::{file_id, shard_of, MetadataPlane, Request, Response};
use pscnf::interval::Range;
use pscnf::util::rng::Rng;

/// Deterministic pseudo-random request trace over `nfiles` files and
/// `nclients` clients, exercising every request variant.
fn random_trace(seed: u64, len: usize, nfiles: usize, nclients: u32) -> Vec<Request> {
    let mut rng = Rng::seed_from_u64(seed);
    let files: Vec<u64> = (0..nfiles)
        .map(|i| file_id(&format!("/trace/file.{i}")))
        .collect();
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        let file = files[rng.gen_range_u64(nfiles as u64) as usize];
        let client = rng.gen_range_u64(nclients as u64) as u32;
        let start = rng.gen_range_u64(64) * 512;
        let len_b = (1 + rng.gen_range_u64(32)) * 512;
        let range = Range::at(start, len_b);
        out.push(match rng.gen_range_u64(9) {
            0 | 1 => Request::Attach {
                file,
                client,
                ranges: vec![range, Range::at(start + 64 * 512, len_b)],
            },
            2 | 3 => Request::Query { file, range },
            4 => Request::QueryFile { file },
            5 => Request::Detach {
                file,
                client,
                range,
            },
            6 => Request::Stat { file },
            7 => Request::Revalidate {
                file,
                // Low version numbers exercise both hit and miss paths
                // early in the trace; both planes see the same per-file
                // version history, so responses must match.
                version: rng.gen_range_u64(4),
            },
            _ => Request::FlushNotify {
                file,
                len: start + len_b,
            },
        });
    }
    out
}

#[test]
fn one_vs_eight_shard_trace_equivalence() {
    // The ISSUE's acceptance anchor: replay identical traces against a
    // 1-shard and an 8-shard plane; every response must match, as must
    // the aggregate bookkeeping.
    for seed in [7u64, 42, 1234] {
        let trace = random_trace(seed, 4000, 24, 8);
        let mut p1 = MetadataPlane::new(1);
        let mut p8 = MetadataPlane::new(8);
        for (i, req) in trace.into_iter().enumerate() {
            let a = p1.handle(req.clone());
            let b = p8.handle(req.clone());
            assert_eq!(a, b, "seed {seed}, request {i}: {req:?}");
        }
        assert_eq!(p1.requests_handled(), p8.requests_handled());
        assert_eq!(p1.total_intervals(), p8.total_intervals());
    }
}

#[test]
fn detach_file_trace_equivalence() {
    // DetachFile touches whole-file state; interleave it with attaches
    // to stress the path the random trace hits rarely.
    let files: Vec<u64> = (0..12).map(|i| file_id(&format!("/df/{i}"))).collect();
    let mut p1 = MetadataPlane::new(1);
    let mut p8 = MetadataPlane::new(8);
    let mut apply = |req: Request| {
        let a = p1.handle(req.clone());
        let b = p8.handle(req.clone());
        assert_eq!(a, b, "{req:?}");
    };
    for round in 0..6u64 {
        for (i, &file) in files.iter().enumerate() {
            apply(Request::Attach {
                file,
                client: (i % 3) as u32,
                ranges: vec![Range::at(round * 100, 50)],
            });
        }
        for (i, &file) in files.iter().enumerate() {
            if (i as u64 + round) % 3 == 0 {
                apply(Request::DetachFile {
                    file,
                    client: (i % 3) as u32,
                });
            }
            apply(Request::QueryFile { file });
        }
    }
}

#[test]
fn same_file_always_routes_to_same_shard() {
    for shards in [1usize, 2, 4, 8, 16] {
        for i in 0..200 {
            let f = file_id(&format!("/route/{i}"));
            let first = shard_of(f, shards);
            assert!(first < shards);
            // Stability across repeated calls and across Request variants
            // (every variant routes by Request::file()).
            assert_eq!(first, shard_of(f, shards));
            let reqs = [
                Request::Stat { file: f },
                Request::QueryFile { file: f },
                Request::FlushNotify { file: f, len: 1 },
            ];
            for r in reqs {
                assert_eq!(shard_of(r.file(), shards), first);
            }
        }
    }
}

#[test]
fn plane_state_partition_is_disjoint_and_complete() {
    // After a trace, the union of per-shard interval counts equals the
    // plane total, and each file's intervals live on exactly its routed
    // shard — no file is split or duplicated across shards.
    let trace = random_trace(99, 2000, 16, 4);
    let mut plane = MetadataPlane::new(8);
    for req in trace {
        plane.handle(req);
    }
    let per_shard: usize = (0..8).map(|s| plane.shard(s).total_intervals()).sum();
    assert_eq!(per_shard, plane.total_intervals());
    for i in 0..16 {
        let f = file_id(&format!("/trace/file.{i}"));
        let owner = plane.shard_index(f);
        for s in 0..8 {
            let n = plane.shard(s).intervals_of(f);
            if s == owner {
                assert_eq!(n, plane.intervals_of(f));
            } else {
                assert_eq!(n, 0, "file {i} leaked onto shard {s}");
            }
        }
    }
}

#[test]
fn responses_never_depend_on_unrelated_files() {
    // Per-file isolation (the property that makes sharding sound):
    // interleaving traffic on OTHER files must not change a file's
    // responses. Run file A's requests alone, then interleaved with
    // noise on other files; the responses to A must be identical.
    let a = file_id("/iso/target");
    let a_reqs = vec![
        Request::Attach {
            file: a,
            client: 1,
            ranges: vec![Range::new(0, 100)],
        },
        Request::Query {
            file: a,
            range: Range::new(0, 200),
        },
        Request::Attach {
            file: a,
            client: 2,
            ranges: vec![Range::new(50, 150)],
        },
        Request::QueryFile { file: a },
        Request::Detach {
            file: a,
            client: 1,
            range: Range::new(0, 50),
        },
        Request::Stat { file: a },
    ];
    let mut alone = MetadataPlane::new(4);
    let alone_resps: Vec<Response> = a_reqs.iter().cloned().map(|r| alone.handle(r)).collect();

    let mut noisy = MetadataPlane::new(4);
    let noise = random_trace(5, 300, 10, 4);
    let mut noise_iter = noise.into_iter();
    let mut noisy_resps = Vec::new();
    for req in a_reqs {
        for n in noise_iter.by_ref().take(40) {
            noisy.handle(n);
        }
        noisy_resps.push(noisy.handle(req));
    }
    assert_eq!(alone_resps, noisy_resps);
}
