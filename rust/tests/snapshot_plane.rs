//! Integration guards for the version-stamped snapshot plane and
//! client-side write coalescing (DESIGN.md §Snapshot-Versioning):
//!
//! - coalesced-attach visibility is bit-for-bit identical to
//!   uncoalesced (property test over random write schedules through
//!   CommitFS and SessionFS: read-back bytes AND server owner maps);
//! - an m-write contiguous phase attaches ≤ ⌈m / merge-run⌉ intervals
//!   with unchanged read-back bytes;
//! - a warm-session reopen issues a `Revalidate` priced at ZERO
//!   interval units on the DES fabric (not a full `bfs_query_file`);
//! - a stale-version client revalidates to the new snapshot after a
//!   remote `session_close` (litmus);
//! - `Range`-overflow offsets surface `BfsError::RangeOverflow`
//!   instead of panicking (regression: `offset = u64::MAX - 4`).

use pscnf::basefs::{BfsError, DesFabric, Request, TestFabric};
use pscnf::fs::{CommitFs, SessionFs, WorkloadFs};
use pscnf::interval::{OwnedInterval, Range};
use pscnf::sim::SimOp;
use pscnf::testkit;

/// One random write schedule: (writer index 0/1, offset, len, fill).
type Schedule = Vec<(usize, u64, u64, u8)>;

const UNIVERSE: u64 = 256;

fn gen_schedule(g: &mut testkit::Gen) -> Schedule {
    g.vec_of(24, |g| {
        let off = g.u64(0, UNIVERSE - 1);
        let len = g.u64(1, (UNIVERSE - off).min(32));
        (g.usize(0, 1), off, len, g.u64(1, 255) as u8)
    })
}

/// Run a schedule through CommitFS (writers commit at the end, reader
/// queries per read); returns (read-back bytes, server owner map,
/// attach interval count actually stored).
fn run_commit(schedule: &Schedule, coalesce: bool) -> (Vec<u8>, Vec<OwnedInterval>, usize) {
    let mut fabric = TestFabric::new(3);
    let mut w: Vec<CommitFs> = (0..2).map(|i| CommitFs::new(i, fabric.bb_of(i))).collect();
    for fs in w.iter_mut() {
        fs.core().set_coalesce(coalesce);
    }
    let mut file = 0;
    for fs in w.iter_mut() {
        file = fs.open(&mut fabric, "/coalesce/commit");
    }
    for &(who, off, len, fill) in schedule {
        CommitFs::write_at(&mut w[who], &mut fabric, file, off, &vec![fill; len as usize])
            .unwrap();
    }
    for fs in w.iter_mut() {
        fs.commit(&mut fabric, file).unwrap();
    }
    let mut r = CommitFs::new(2, fabric.bb_of(2));
    r.open(&mut fabric, "/coalesce/commit");
    let bytes = CommitFs::read_at(&mut r, &mut fabric, file, Range::new(0, UNIVERSE)).unwrap();
    let map = fabric
        .inner
        .server
        .handle(Request::QueryFile { file })
        .intervals();
    let stored = fabric.inner.server.intervals_of(file);
    (bytes, map, stored)
}

/// Same schedule through SessionFS (close-to-open).
fn run_session(schedule: &Schedule, coalesce: bool) -> (Vec<u8>, Vec<OwnedInterval>) {
    let mut fabric = TestFabric::new(3);
    let mut w: Vec<SessionFs> = (0..2).map(|i| SessionFs::new(i, fabric.bb_of(i))).collect();
    for fs in w.iter_mut() {
        fs.core().set_coalesce(coalesce);
    }
    let mut file = 0;
    for fs in w.iter_mut() {
        file = fs.open(&mut fabric, "/coalesce/session");
    }
    for &(who, off, len, fill) in schedule {
        SessionFs::write_at(&mut w[who], &mut fabric, file, off, &vec![fill; len as usize])
            .unwrap();
    }
    for fs in w.iter_mut() {
        fs.session_close(&mut fabric, file).unwrap();
    }
    let mut r = SessionFs::new(2, fabric.bb_of(2));
    r.open(&mut fabric, "/coalesce/session");
    r.session_open(&mut fabric, file).unwrap();
    let bytes = SessionFs::read_at(&mut r, &mut fabric, file, Range::new(0, UNIVERSE)).unwrap();
    let map = fabric
        .inner
        .server
        .handle(Request::QueryFile { file })
        .intervals();
    (bytes, map)
}

#[test]
fn coalesced_attach_visibility_is_bit_for_bit_uncoalesced() {
    testkit::check("coalesced == uncoalesced visibility", |g| {
        let schedule = gen_schedule(g);
        let (b_on, m_on, stored_on) = run_commit(&schedule, true);
        let (b_off, m_off, stored_off) = run_commit(&schedule, false);
        testkit::ensure(b_on == b_off, "commit read-back diverged")?;
        testkit::ensure(m_on == m_off, "commit owner map diverged")?;
        // Coalescing may only shrink (or keep) the stored interval set.
        testkit::ensure(
            stored_on <= stored_off,
            format!("coalescing grew the tree: {stored_on} > {stored_off}"),
        )?;
        let (b_on, m_on) = run_session(&schedule, true);
        let (b_off, m_off) = run_session(&schedule, false);
        testkit::ensure(b_on == b_off, "session read-back diverged")?;
        testkit::ensure(m_on == m_off, "session owner map diverged")
    });
}

#[test]
fn contiguous_write_phase_attaches_one_interval_per_run() {
    // m = 16 small writes forming TWO file-contiguous runs (interleaved
    // in time, so their burst-buffer placements never merge locally):
    // the attach must ship ⌈m / merge-run⌉ = 2 intervals, and read-back
    // must be unchanged bytes.
    let m = 16u64;
    let run_len = m / 2;
    let s = 8u64;
    let region_b = 1 << 20;
    let mut fabric = TestFabric::new(2);
    let mut w = CommitFs::new(0, fabric.bb_of(0));
    let file = w.open(&mut fabric, "/runs");
    for i in 0..run_len {
        CommitFs::write_at(&mut w, &mut fabric, file, i * s, &vec![0xA; s as usize]).unwrap();
        CommitFs::write_at(
            &mut w,
            &mut fabric,
            file,
            region_b + i * s,
            &vec![0xB; s as usize],
        )
        .unwrap();
    }
    let intervals_before = fabric.inner.counters.rpc_intervals;
    w.commit(&mut fabric, file).unwrap();
    let shipped = fabric.inner.counters.rpc_intervals - intervals_before;
    assert_eq!(fabric.inner.counters.rpcs, 1, "one attach RPC");
    assert_eq!(shipped, 2, "⌈{m}/{run_len}⌉ = 2 coalesced intervals");
    assert_eq!(fabric.inner.server.intervals_of(file), 2);

    let mut r = CommitFs::new(1, fabric.bb_of(1));
    r.open(&mut fabric, "/runs");
    let a = CommitFs::read_at(&mut r, &mut fabric, file, Range::new(0, run_len * s)).unwrap();
    assert_eq!(a, vec![0xA; (run_len * s) as usize]);
    let b = CommitFs::read_at(
        &mut r,
        &mut fabric,
        file,
        Range::at(region_b, run_len * s),
    )
    .unwrap();
    assert_eq!(b, vec![0xB; (run_len * s) as usize]);
}

#[test]
fn warm_reopen_is_priced_as_zero_interval_revalidate() {
    // DES fabric-counter assertion: the warm session_open issues a
    // Revalidate — SimOp::Rpc { intervals: 0 } — not a full
    // bfs_query_file, and rpc_intervals does not grow on the hit.
    let mut fabric = DesFabric::new(vec![0, 0]);
    let mut w = SessionFs::new(0, fabric.bb_of(0));
    let mut r = SessionFs::new(1, fabric.bb_of(1));
    let f = w.open(&mut fabric, "/priced");
    r.open(&mut fabric, "/priced");
    SessionFs::write_at(&mut w, &mut fabric, f, 0, &[1u8; 512]).unwrap();
    w.session_close(&mut fabric, f).unwrap();
    while fabric.pop_cost(0).is_some() {}

    // Cold open: full snapshot, ≥1 interval priced.
    r.session_open(&mut fabric, f).unwrap();
    assert_eq!(
        fabric.pop_cost(1),
        Some(SimOp::Rpc {
            intervals: 1,
            shard: 0
        }),
        "cold open ships the map"
    );
    r.session_close(&mut fabric, f).unwrap();
    assert_eq!(fabric.pop_cost(1), None, "readers publish nothing");

    let intervals_before = fabric.counters.rpc_intervals;
    r.session_open(&mut fabric, f).unwrap();
    assert_eq!(
        fabric.pop_cost(1),
        Some(SimOp::Rpc {
            intervals: 0,
            shard: 0
        }),
        "warm reopen must be a zero-interval Revalidate"
    );
    assert_eq!(fabric.counters.rpc_intervals, intervals_before);
    assert_eq!(fabric.counters.revalidates, 1);
    assert_eq!(fabric.counters.revalidate_hits, 1);
}

#[test]
fn stale_reopen_after_one_edit_ships_one_interval_unit_not_the_map() {
    // Acceptance (O(changes) metadata traffic): a warm reader that is
    // ONE published edit behind a 1000-interval file revalidates into a
    // `Response::Delta` priced at 1 interval unit on the DES fabric —
    // not the 1000-interval map a full snapshot would re-ship.
    let mut fabric = DesFabric::new(vec![0, 0]);
    let mut w = SessionFs::new(0, fabric.bb_of(0));
    let mut r = SessionFs::new(1, fabric.bb_of(1));
    let f = w.open(&mut fabric, "/ok-units");
    r.open(&mut fabric, "/ok-units");
    // 1000 disjoint, non-touching blocks → one attach of 1000 intervals.
    for i in 0..1000u64 {
        SessionFs::write_at(&mut w, &mut fabric, f, i * 16, &[7u8; 8]).unwrap();
    }
    w.session_close(&mut fabric, f).unwrap();
    while fabric.pop_cost(0).is_some() {}

    // The cold open pays the whole map once...
    r.session_open(&mut fabric, f).unwrap();
    assert_eq!(
        fabric.pop_cost(1),
        Some(SimOp::Rpc {
            intervals: 1000,
            shard: 0
        }),
        "cold open ships the whole map"
    );
    r.session_close(&mut fabric, f).unwrap();

    // ... the writer publishes ONE more block ...
    SessionFs::write_at(&mut w, &mut fabric, f, 20_000, &[9u8; 8]).unwrap();
    w.session_close(&mut fabric, f).unwrap();
    while fabric.pop_cost(0).is_some() {}

    // ... and the stale reopen ships O(k) = 1 unit, not 1000.
    let intervals_before = fabric.counters.rpc_intervals;
    r.session_open(&mut fabric, f).unwrap();
    assert_eq!(
        fabric.pop_cost(1),
        Some(SimOp::Rpc {
            intervals: 1,
            shard: 0
        }),
        "a 1-edit stale reopen must be priced at 1 interval unit"
    );
    assert_eq!(fabric.counters.rpc_intervals - intervals_before, 1);
    assert_eq!(fabric.counters.delta_rpcs, 1);
    assert_eq!(fabric.counters.delta_edits, 1);
    assert_eq!(fabric.counters.revalidates, 1);
    assert_eq!(fabric.counters.revalidate_hits, 0, "stale is not a hit");
    // The applied delta really produced the current map: the reader
    // sees the new block through it.
    assert_eq!(
        SessionFs::read_at(&mut r, &mut fabric, f, Range::at(20_000, 8)).unwrap(),
        vec![9u8; 8]
    );
}

#[test]
fn stale_client_revalidates_to_remote_close_snapshot() {
    // Litmus (close-to-open): P0 caches a snapshot and closes; P1
    // writes and session_closes; P0's NEXT session must observe P1's
    // update through a revalidation miss.
    let mut fabric = TestFabric::new(2);
    let mut p0 = SessionFs::new(0, fabric.bb_of(0));
    let mut p1 = SessionFs::new(1, fabric.bb_of(1));
    let f = p0.open(&mut fabric, "/litmus/c2o");
    p1.open(&mut fabric, "/litmus/c2o");

    p0.session_open(&mut fabric, f).unwrap();
    assert_eq!(
        SessionFs::read_at(&mut p0, &mut fabric, f, Range::new(0, 4)).unwrap(),
        vec![0u8; 4]
    );
    p0.session_close(&mut fabric, f).unwrap();

    SessionFs::write_at(&mut p1, &mut fabric, f, 0, b"done").unwrap();
    p1.session_close(&mut fabric, f).unwrap();

    p0.session_open(&mut fabric, f).unwrap();
    assert_eq!(fabric.inner.counters.revalidates, 1);
    assert_eq!(fabric.inner.counters.revalidate_hits, 0, "must miss");
    assert_eq!(
        SessionFs::read_at(&mut p0, &mut fabric, f, Range::new(0, 4)).unwrap(),
        b"done"
    );
}

#[test]
fn range_overflow_is_an_error_not_a_panic() {
    let mut fabric = TestFabric::new(1);
    let mut c = CommitFs::new(0, fabric.bb_of(0));
    let f = c.open(&mut fabric, "/overflow");
    let off = u64::MAX - 4;

    // Adversarial write whose end wraps.
    let err = CommitFs::write_at(&mut c, &mut fabric, f, off, &[0u8; 8]).unwrap_err();
    assert!(
        matches!(err, BfsError::RangeOverflow { offset, len } if offset == off && len == 8),
        "{err:?}"
    );
    // The buffer must be untouched: nothing to commit.
    c.commit(&mut fabric, f).unwrap();
    assert_eq!(fabric.inner.counters.rpcs, 0);

    // Queries and range commits at wrapping offsets error too.
    let err = c.core().query(&mut fabric, f, off, 8).unwrap_err();
    assert!(matches!(err, BfsError::RangeOverflow { .. }), "{err:?}");
    let err = c.commit_range(&mut fabric, f, off, 8).unwrap_err();
    assert!(matches!(err, BfsError::RangeOverflow { .. }), "{err:?}");

    // The exact boundary still works: [MAX-4, MAX) is a valid range.
    assert!(Range::checked_at(off, 4).is_some());
}
