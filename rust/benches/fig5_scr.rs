//! Fig 5 — HACC-IO with SCR: checkpoint and restart bandwidth vs node
//! count (Partner scheme, 10 M particles, one spare node, single-node
//! failure; restart reads served from memory buffers).
//!
//! Paper shape to reproduce (§6.2): checkpoint bandwidth ~identical
//! under commit and session and scaling ~linearly (SSD-bound); restart
//! bandwidth scales under session but collapses under commit as the
//! per-read query RPCs pile onto the global server.

use pscnf::config::Testbed;
use pscnf::coordinator::{sweep_scr, write_results};
use pscnf::fs::FsKind;
use pscnf::util::json::Json;
use pscnf::util::table::Table;
use pscnf::util::units::fmt_bandwidth;

fn main() {
    let nodes = [3usize, 4, 8, 16];
    let rows = sweep_scr(
        &nodes,
        &[FsKind::Commit, FsKind::Session],
        12,
        10_000_000,
        5,
        Testbed::Catalyst,
    );

    let mut ckpt = Table::new(vec!["nodes", "commit", "session"]);
    let mut rst = Table::new(vec!["nodes", "commit", "session"]);
    let mut payload = Json::obj();
    let mut arr = Vec::new();
    for &n in &nodes {
        let get = |fs: FsKind| {
            rows.iter()
                .find(|(f, nn, _, _)| *f == fs && *nn == n)
                .unwrap()
        };
        let (_, _, cck, crs) = get(FsKind::Commit);
        let (_, _, sck, srs) = get(FsKind::Session);
        ckpt.row(vec![
            n.to_string(),
            fmt_bandwidth(cck.mean()),
            fmt_bandwidth(sck.mean()),
        ]);
        rst.row(vec![
            n.to_string(),
            fmt_bandwidth(crs.mean()),
            fmt_bandwidth(srs.mean()),
        ]);
        let mut o = Json::obj();
        o.set("nodes", n)
            .set("commit_ckpt", cck.mean())
            .set("session_ckpt", sck.mean())
            .set("commit_restart", crs.mean())
            .set("session_restart", srs.mean());
        arr.push(o);
    }
    payload.set("rows", Json::Arr(arr));
    println!("Fig 5(a) — SCR checkpoint bandwidth (ppn=12, 10M particles)\n{}", ckpt.render());
    println!("Fig 5(b) — SCR restart bandwidth\n{}", rst.render());
    write_results("fig5_scr", payload);
    println!("results: target/results/fig5_scr.json");
}
