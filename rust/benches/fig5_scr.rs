//! Fig 5 — HACC-IO with SCR: checkpoint and restart bandwidth vs node
//! count (Partner scheme, 10 M particles, one spare node, single-node
//! failure; restart reads served from memory buffers), all four models.
//!
//! Paper shape to reproduce (§6.2): checkpoint bandwidth ~identical
//! under commit and session and scaling ~linearly (SSD-bound); restart
//! bandwidth scales under session but collapses under commit as the
//! per-read query RPCs pile onto the global server.
//!
//! Thin wrapper over the `fig5` family of the bench registry
//! (`pscnf bench --filter fig5` runs the same cells; the `restart_bw`
//! metric is Fig 5b). `--json` writes `target/results/BENCH_fig5.json`.

fn main() {
    pscnf::bench::family_main("fig5");
}
