//! Fig 6 — random-read bandwidth of the DL "Preloaded" ingestion
//! strategy: strong scaling (global mini-batch 1024) and weak scaling
//! (32 samples per process per iteration), 116 KiB samples, 4 procs per
//! node, commit vs session.
//!
//! Paper shape to reproduce (§6.3): session outperforms commit in both
//! bandwidth and scalability, with the gap *growing* with node count —
//! significant even at small scales (the paper's headline ~5×).

use pscnf::config::Testbed;
use pscnf::coordinator::{sweep_dl, write_results};
use pscnf::fs::FsKind;
use pscnf::util::json::Json;
use pscnf::util::table::Table;
use pscnf::util::units::fmt_bandwidth;

fn main() {
    let nodes = [1usize, 2, 4, 8, 16];
    let mut payload = Json::obj();
    for (strong, label, work) in [(true, "strong", 4), (false, "weak", 8)] {
        let rows = sweep_dl(
            strong,
            &nodes,
            &[FsKind::Commit, FsKind::Session],
            4,
            work,
            5,
            Testbed::Catalyst,
        );
        let mut t = Table::new(vec!["nodes", "commit", "session", "ratio"]);
        let mut arr = Vec::new();
        for &n in &nodes {
            let get = |fs: FsKind| {
                rows.iter()
                    .find(|(f, nn, _)| *f == fs && *nn == n)
                    .unwrap()
            };
            let (_, _, c) = get(FsKind::Commit);
            let (_, _, s) = get(FsKind::Session);
            t.row(vec![
                n.to_string(),
                fmt_bandwidth(c.mean()),
                fmt_bandwidth(s.mean()),
                format!("{:.2}x", s.mean() / c.mean()),
            ]);
            let mut o = Json::obj();
            o.set("nodes", n)
                .set("commit", c.mean())
                .set("session", s.mean());
            arr.push(o);
        }
        println!(
            "Fig 6 — DL random-read bandwidth, {label} scaling (ppn=4, 116KiB samples)\n{}",
            t.render()
        );
        payload.set(label, Json::Arr(arr));
    }
    write_results("fig6_dl", payload);
    println!("results: target/results/fig6_dl.json");
}
