//! Fig 6 — random-read bandwidth of the DL "Preloaded" ingestion
//! strategy: strong scaling (global mini-batch 1024) and weak scaling
//! (32 samples per process per iteration), 116 KiB samples, 4 procs per
//! node, all four consistency models.
//!
//! Paper shape to reproduce (§6.3): session outperforms commit in both
//! bandwidth and scalability, with the gap *growing* with node count —
//! significant even at small scales (the paper's headline ~5×).
//!
//! Thin wrapper over the `fig6` family of the bench registry
//! (`pscnf bench --filter fig6` runs the same cells). `--json`
//! additionally writes `target/results/BENCH_fig6.json`.

fn main() {
    pscnf::bench::family_main("fig6");
}
