//! Ablation — request aggregation in the DL ingestion path (§6.3): the
//! paper's benchmark deliberately does NOT aggregate same-destination
//! sample transfers, "which places additional stress on the file
//! system". This bench quantifies that choice: aggregating ownership
//! queries per owner-group (`dl.weak.agg` rows) recovers much of commit
//! consistency's gap vs session, i.e. the Fig 6 separation depends on
//! unaggregated small requests — exactly the regime the paper argues
//! stresses strong consistency.
//!
//! Thin wrapper over the `ablate_dl_aggregation` family of the bench
//! registry. `--json` additionally writes
//! `target/results/BENCH_ablate_dl_aggregation.json`.

fn main() {
    pscnf::bench::family_main("ablate_dl_aggregation");
}
