//! Ablation — request aggregation in the DL ingestion path (§6.3): the
//! paper's benchmark deliberately does NOT aggregate same-destination
//! sample transfers, "which places additional stress on the file
//! system". This bench quantifies that choice: aggregating ownership
//! queries per owner-group recovers much of commit consistency's gap,
//! i.e. the Fig 6 separation depends on unaggregated small requests —
//! exactly the regime the paper argues stresses strong consistency.

use pscnf::config::Testbed;
use pscnf::dl::{DlDriver, DlParams};
use pscnf::fs::FsKind;
use pscnf::util::table::Table;
use pscnf::util::units::fmt_bandwidth;

fn main() {
    let mut t = Table::new(vec![
        "nodes",
        "commit",
        "commit+aggregation",
        "session",
    ]);
    for nodes in [2usize, 4, 8, 16] {
        let mk = |aggregate| {
            let mut p = DlParams::weak(nodes, 4, 8, 11);
            p.aggregate = aggregate;
            p
        };
        let commit = DlDriver::new(FsKind::Commit, mk(false))
            .run(Testbed::Catalyst.cluster(nodes, 5));
        let agg = DlDriver::new(FsKind::Commit, mk(true))
            .run(Testbed::Catalyst.cluster(nodes, 5));
        let session = DlDriver::new(FsKind::Session, mk(false))
            .run(Testbed::Catalyst.cluster(nodes, 5));
        t.row(vec![
            nodes.to_string(),
            fmt_bandwidth(commit.read_bw()),
            fmt_bandwidth(agg.read_bw()),
            fmt_bandwidth(session.read_bw()),
        ]);
    }
    println!(
        "DL aggregation ablation — weak scaling, ppn=4, 116KiB samples\n\
         (expected: aggregation recovers much of commit's deficit;\n\
         session still wins without any aggregation effort)\n\n{}",
        t.render()
    );
}
