//! Ablation — version-stamped ownership snapshots. The paper's
//! headline ~5x session-vs-commit gap on small reads rests on clients
//! *caching* ownership maps instead of querying per read; this bench
//! measures the step beyond: a warm `session_open`/`MPI_File_sync`
//! sends a lightweight `Revalidate` (a version compare, zero interval
//! units) and only transfers the map when some other client attached
//! in between.
//!
//! Workload: one contiguous write phase, then the reader half runs
//! `r` sessions of small random reads each (scale tags `n4.r<rounds>`).
//! Expected shape: the caching models' `revalidate_hit_rate` climbs
//! toward 1.0 with rounds and their RPC count stays flat per session,
//! while commit/posix RPCs scale with the read count. Writes are
//! client-coalesced before attach, so `rpc_intervals` doubles as the
//! coalescing-factor gauge.
//!
//! Thin wrapper over the `ablate_snapshot` family of the bench
//! registry. `--json` additionally writes
//! `target/results/BENCH_ablate_snapshot.json`.

fn main() {
    pscnf::bench::family_main("ablate_snapshot");
}
