//! Ablation — device-speed sensitivity (§6.4, third takeaway): the
//! faster the storage device, the more the consistency model matters.
//! Runs CC-R with 8 KiB reads across HDD / Catalyst SSD / Expanse NVMe /
//! pmem device models under all four models; the session:commit ratio
//! grows as the device gets faster.
//!
//! Thin wrapper over the `ablate_device` family of the bench registry
//! (scale tags `<testbed>.n8`). `--json` additionally writes
//! `target/results/BENCH_ablate_device.json`.

fn main() {
    pscnf::bench::family_main("ablate_device");
}
