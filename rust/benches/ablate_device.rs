//! Ablation — device-speed sensitivity (§6.4, third takeaway): the
//! faster the storage device, the more the consistency model matters.
//! Runs CC-R with 8 KiB reads across HDD / Catalyst SSD / Expanse NVMe /
//! pmem device models and reports the session:commit ratio.

use pscnf::config::Testbed;
use pscnf::coordinator::{sweep_synthetic, write_results};
use pscnf::fs::FsKind;
use pscnf::util::json::Json;
use pscnf::util::table::Table;
use pscnf::util::units::fmt_bandwidth;
use pscnf::workload::Config;

fn main() {
    let mut t = Table::new(vec!["device", "commit", "session", "session/commit"]);
    let mut payload = Json::obj();
    for testbed in [Testbed::Hdd, Testbed::Catalyst, Testbed::Expanse, Testbed::Pmem] {
        let cells = sweep_synthetic(
            Config::CcR,
            8 << 10,
            &[8],
            &[FsKind::Commit, FsKind::Session],
            12,
            10,
            3,
            testbed,
            false,
        );
        let commit = cells.iter().find(|c| c.fs == FsKind::Commit).unwrap();
        let session = cells.iter().find(|c| c.fs == FsKind::Session).unwrap();
        let ratio = session.bw.mean() / commit.bw.mean();
        t.row(vec![
            testbed.name().to_string(),
            fmt_bandwidth(commit.bw.mean()),
            fmt_bandwidth(session.bw.mean()),
            format!("{ratio:.2}x"),
        ]);
        let mut o = Json::obj();
        o.set("commit", commit.bw.mean())
            .set("session", session.bw.mean())
            .set("ratio", ratio);
        payload.set(testbed.name(), o);
    }
    println!(
        "Device ablation — CC-R, 8KiB reads, 8 nodes x 12 procs\n\
         (expected: ratio grows as the device gets faster)\n\n{}",
        t.render()
    );
    write_results("ablate_device", payload);
}
