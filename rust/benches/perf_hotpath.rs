//! Thin wrapper over the `perf_hotpath` registry family: wall-clock
//! microbenches of the simulator itself (engine events/s via the pure
//! event-loop flood and the fig4-cell end-to-end run, ns/op for the L3
//! hot structures). The cells live in `bench::registry` like every
//! other family; the fig4cell cell is in the gated smoke subset.

fn main() {
    pscnf::bench::family_main("perf_hotpath");
}
