//! §Perf harness — wall-clock microbenchmarks of the L3 hot paths:
//! global interval tree ops, server request handling, DES event
//! throughput, and a full Fig-4-cell end-to-end run. Criterion is not
//! available offline; this uses a warmup+repeat harness with
//! mean/stddev, printed as a table (units: ns/op or events/s).

use pscnf::basefs::{GlobalServerState, Request};
use pscnf::config::Testbed;
use pscnf::fs::FsKind;
use pscnf::interval::{GlobalIntervalTree, Range};
use pscnf::util::rng::Rng;
use pscnf::util::stats::Samples;
use pscnf::util::table::Table;
use pscnf::workload::{Config, SyntheticDriver};
use std::time::Instant;

/// Run `f` (which performs `ops_per_iter` operations) with warmup, and
/// report ns/op samples.
fn bench(repeats: usize, ops_per_iter: u64, mut f: impl FnMut()) -> Samples {
    f(); // warmup
    let mut s = Samples::new();
    for _ in 0..repeats {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_nanos() as f64 / ops_per_iter as f64);
    }
    s
}

fn main() {
    let mut t = Table::new(vec!["hot path", "ns/op (mean)", "stddev", "ops/s"]);
    let mut add = |name: &str, s: &Samples| {
        let m = s.mean();
        t.row(vec![
            name.to_string(),
            format!("{m:.0}"),
            format!("{:.0}", s.stddev()),
            format!("{:.0}", 1e9 / m),
        ]);
    };

    // 1. Global interval tree: attach (split-heavy random pattern).
    const N: u64 = 20_000;
    let s = bench(10, N, || {
        let mut tree = GlobalIntervalTree::new();
        let mut rng = Rng::seed_from_u64(1);
        for i in 0..N {
            let start = rng.gen_range_u64(1 << 20);
            tree.attach(Range::at(start, 64 + (i % 512)), (i % 16) as u32);
        }
    });
    add("gtree attach (random)", &s);

    // 2. Global interval tree: query on a populated tree.
    let mut tree = GlobalIntervalTree::new();
    let mut rng = Rng::seed_from_u64(2);
    for i in 0..N {
        tree.attach(Range::at(rng.gen_range_u64(1 << 20), 256), (i % 16) as u32);
    }
    let s = bench(10, N, || {
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..N {
            let q = tree.query(Range::at(rng.gen_range_u64(1 << 20), 4096));
            std::hint::black_box(q);
        }
    });
    add("gtree query (4KiB range)", &s);

    // 3. Server request handling (attach+query mix).
    let s = bench(10, N, || {
        let mut server = GlobalServerState::new();
        let mut rng = Rng::seed_from_u64(4);
        for i in 0..N {
            let start = rng.gen_range_u64(1 << 20);
            if i % 3 == 0 {
                let resp = server.handle(Request::Query {
                    file: 1,
                    range: Range::at(start, 8192),
                });
                std::hint::black_box(resp);
            } else {
                server.handle(Request::Attach {
                    file: 1,
                    client: (i % 16) as u32,
                    ranges: vec![Range::at(start, 512)],
                });
            }
        }
    });
    add("server handle (2:1 attach:query)", &s);

    // 4. DES end-to-end: one Fig-4 cell (16 nodes x 12p, 8KiB CC-R).
    let cell_events = {
        // count ops once
        let params = Config::CcR.params(16, 12, 8 << 10, 10, 7);
        let driver = SyntheticDriver::new(FsKind::Commit, params);
        let rep = driver.run(Testbed::Catalyst.cluster(16, 1));
        std::hint::black_box(&rep);
        rep.rpcs * 4 // rough op count proxy, avoids plumbing
    };
    let t0 = Instant::now();
    let mut runs = 0u32;
    while t0.elapsed().as_secs_f64() < 2.0 {
        let params = Config::CcR.params(16, 12, 8 << 10, 10, 7);
        let driver = SyntheticDriver::new(FsKind::Commit, params);
        std::hint::black_box(driver.run(Testbed::Catalyst.cluster(16, runs as u64)));
        runs += 1;
    }
    let per_run_ms = t0.elapsed().as_secs_f64() * 1e3 / runs as f64;
    t.row(vec![
        "fig4 cell e2e (16n x 12p commit)".to_string(),
        format!("{:.2}ms/run", per_run_ms),
        "-".to_string(),
        format!("{runs} runs/2s"),
    ]);
    let _ = cell_events;

    println!("L3 hot-path microbenchmarks\n\n{}", t.render());
}
