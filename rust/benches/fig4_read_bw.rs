//! Fig 4 — read bandwidth of CC-R and CS-R with 8 MiB and 8 KiB access
//! sizes, 2–16 nodes × 12 procs, under all four consistency models.
//!
//! Paper shape to reproduce (§6.1.2):
//! - CC-R > CS-R under both models and sizes (strided reads contend);
//! - 8 MiB: consistency model impact negligible;
//! - 8 KiB: session beats commit in bandwidth AND scalability (the
//!   per-read query RPC saturates the global server's master thread);
//!   session shows visibly higher variance (aged-SSD small-read jitter).
//!
//! Thin wrapper over the `fig4` family of the bench registry
//! (`pscnf bench --filter fig4` runs the same cells). `--json`
//! additionally writes `target/results/BENCH_fig4.json`.

fn main() {
    pscnf::bench::family_main("fig4");
}
