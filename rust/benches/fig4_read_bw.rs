//! Fig 4 — read bandwidth of CC-R and CS-R with 8 MiB and 8 KiB access
//! sizes, commit vs session, 2–16 nodes × 12 procs.
//!
//! Paper shape to reproduce (§6.1.2):
//! - CC-R > CS-R under both models and sizes (strided reads contend);
//! - 8 MiB: consistency model impact negligible;
//! - 8 KiB: session beats commit in bandwidth AND scalability (the
//!   per-read query RPC saturates the global server's master thread);
//!   session shows visibly higher variance (aged-SSD small-read jitter).

use pscnf::config::Testbed;
use pscnf::coordinator::{render_sweep, sweep_synthetic, write_results};
use pscnf::fs::FsKind;
use pscnf::util::json::Json;
use pscnf::util::units::fmt_bytes;
use pscnf::workload::Config;

fn main() {
    let nodes = [2usize, 4, 8, 16];
    let fs = [FsKind::Commit, FsKind::Session];
    let mut all = Json::obj();
    for config in [Config::CcR, Config::CsR] {
        for access in [8u64 << 20, 8 << 10] {
            let cells = sweep_synthetic(
                config,
                access,
                &nodes,
                &fs,
                12,
                10,
                5,
                Testbed::Catalyst,
                false,
            );
            println!(
                "{}\n",
                render_sweep(
                    &format!(
                        "Fig 4 — {} read bandwidth, access={} (ppn=12, m=10)",
                        config.name(),
                        fmt_bytes(access)
                    ),
                    &cells
                )
            );
            all.set(
                &format!("{}_{}", config.name(), fmt_bytes(access)),
                Json::Arr(cells.iter().map(|c| c.to_json()).collect()),
            );
        }
    }
    write_results("fig4_read_bw", all);

    // Headline check printed for EXPERIMENTS.md: session/commit ratio at
    // 8 KiB, largest scale.
    let cells = sweep_synthetic(
        Config::CcR,
        8 << 10,
        &[16],
        &fs,
        12,
        10,
        5,
        Testbed::Catalyst,
        false,
    );
    let commit = cells.iter().find(|c| c.fs == FsKind::Commit).unwrap();
    let session = cells.iter().find(|c| c.fs == FsKind::Session).unwrap();
    println!(
        "headline: 8KiB CC-R @16 nodes  session/commit = {:.2}x",
        session.bw.mean() / commit.bw.mean()
    );
    println!("results: target/results/fig4_read_bw.json");
}
