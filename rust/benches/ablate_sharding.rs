//! Ablation — metadata-plane sharding. The global server's serial
//! master dispatch is the choke point for commit consistency's
//! per-read queries (`ablate_server` shows adding workers is flat);
//! this bench shows that *sharding the plane* — N independent
//! master+worker groups with files hash-partitioned across them — is
//! what actually scales it. CommitFS small-random-read CC-R, the
//! workload where the paper's ~5x session-vs-commit gap lives, with
//! the dataset striped over 32 files to give the router something to
//! spread.
//!
//! Expected shape: bandwidth improves monotonically (then saturates)
//! as shards go 1 → 16 — sharding changes performance, not semantics
//! (the trace-equivalence test in tests/shard_plane.rs proves the
//! latter).
//!
//! Thin wrapper over the `ablate_sharding` family of the bench registry
//! (scale tags `s<shards>`). `--json` additionally writes
//! `target/results/BENCH_ablate_sharding.json`.

fn main() {
    pscnf::bench::family_main("ablate_sharding");
}
