//! Ablation — metadata-plane sharding. The global server's serial
//! master dispatch is the choke point for commit consistency's
//! per-read queries (`ablate_server` shows adding workers is flat);
//! this bench shows that *sharding the plane* — N independent
//! master+worker groups with files hash-partitioned across them — is
//! what actually scales it. CommitFS small-random-read CC-R, the
//! workload where the paper's ~5x session-vs-commit gap lives, with
//! the dataset striped over enough files to give the router something
//! to spread.
//!
//! Expected shape: bandwidth improves monotonically (then saturates)
//! as shards go 1 → 16, while the 1-shard row matches `ablate_server`'s
//! baseline — sharding changes performance, not semantics (the
//! trace-equivalence test in tests/shard_plane.rs proves the latter).
//!
//! `--json` additionally writes target/results/BENCH_ablate_sharding.json.

use pscnf::coordinator::maybe_write_bench_json;
use pscnf::fs::FsKind;
use pscnf::sim::{Cluster, NetParams, ServerParams, SsdParams, UpfsParams};
use pscnf::util::json::Json;
use pscnf::util::table::Table;
use pscnf::util::units::fmt_bandwidth;
use pscnf::workload::{Config, Pattern, SyntheticDriver};

const NODES: usize = 8;
const PPN: usize = 12;
const ACCESS: u64 = 8 << 10;
const M: usize = 10;
const FILES: usize = 32;

fn run(shards: usize) -> f64 {
    let mut params = Config::CcR
        .params(NODES, PPN, ACCESS, M, 7)
        .with_files(FILES);
    // Small RANDOM reads: every read queries the plane, offsets (and
    // therefore files, and therefore shards) are spread uniformly.
    params.read_pattern = Some(Pattern::Random);
    let cluster = Cluster::new(
        NODES,
        SsdParams::catalyst(),
        NetParams::ib_qdr(),
        ServerParams::catalyst_sharded(shards),
        UpfsParams::catalyst_lustre(),
        99,
    );
    SyntheticDriver::new_sharded(FsKind::Commit, params, shards)
        .run(cluster)
        .read_bw()
}

fn main() {
    let shard_counts = [1usize, 2, 4, 8, 16];
    let mut t = Table::new(vec!["shards", "read bw", "vs 1 shard"]);
    let mut rows = Vec::new();
    let base = run(1);
    for &shards in &shard_counts {
        let bw = if shards == 1 { base } else { run(shards) };
        t.row(vec![
            shards.to_string(),
            fmt_bandwidth(bw),
            format!("{:.2}x", bw / base),
        ]);
        rows.push((shards, bw));
    }
    println!(
        "Sharding ablation — CommitFS CC-R 8KiB random reads,\n\
         {NODES} nodes x {PPN} procs, dataset striped over {FILES} files\n\
         (expected: monotone improvement then saturation — each shard\n\
         adds serial master dispatch capacity; contrast ablate_server,\n\
         where extra workers behind ONE master stay flat)\n\n{}",
        t.render()
    );

    let mut payload = Json::obj();
    payload
        .set("workload", Config::CcR.name())
        .set("fs", FsKind::Commit.name())
        .set("access_bytes", ACCESS)
        .set("nodes", NODES)
        .set("ppn", PPN)
        .set("files", FILES)
        .set(
            "cells",
            Json::Arr(
                rows.iter()
                    .map(|&(shards, bw)| {
                        let mut o = Json::obj();
                        o.set("shards", shards).set("read_bw", bw);
                        o
                    })
                    .collect(),
            ),
        );
    maybe_write_bench_json("ablate_sharding", payload);
}
