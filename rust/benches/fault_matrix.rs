//! Crash-recovery pricing — what a metadata-plane outage costs each
//! consistency model. Every registered model × shard count runs one
//! CC-R cell twice: once healthy (the baseline probe), once with a
//! whole-plane kill/restart whose window ends at the write barrier's
//! release, so lease fencing and — for replay-to-SC models — attachment
//! replay are priced right before the readers unblock. The headline
//! metric is `recovery_s`, the virtual makespan the outage added.
//!
//! Expected shape: replay-to-SC models (posix/commit/session/mpiio/
//! commit_strict) pay fences plus replayed intervals and recover the
//! exact SC outcome; eventual/cto pay fences only — their obligation is
//! permitted-stale, so there is nothing to replay (the conformance side
//! of this split is proved in tests/fault_conformance.rs).
//!
//! Thin wrapper over the `fault_matrix` family of the bench registry
//! (scale tags `s<shards>`). `--json` additionally writes
//! `target/results/BENCH_fault_matrix.json`.

fn main() {
    pscnf::bench::family_main("fault_matrix");
}
