//! Ablation — global-server design choices (§5.1.2): worker-pool width
//! and dispatch policy. The paper's server uses a master thread with
//! round-robin FIFO workers; this bench shows (a) the master, not the
//! workers, is the choke point for commit's per-read queries, and
//! (b) round-robin vs least-loaded dispatch barely matters because
//! query service times are uniform.

use pscnf::fs::FsKind;
use pscnf::sim::{Cluster, Dispatch, NetParams, ServerParams, SsdParams, UpfsParams};
use pscnf::util::table::Table;
use pscnf::util::units::fmt_bandwidth;
use pscnf::workload::{Config, SyntheticDriver};

fn run(workers: usize, dispatch: Dispatch) -> f64 {
    let nodes = 8;
    let params = Config::CcR.params(nodes, 12, 8 << 10, 10, 7);
    let server = ServerParams {
        workers,
        dispatch,
        ..ServerParams::catalyst()
    };
    let cluster = Cluster::new(
        nodes,
        SsdParams::catalyst(),
        NetParams::ib_qdr(),
        server,
        UpfsParams::catalyst_lustre(),
        99,
    );
    SyntheticDriver::new(FsKind::Commit, params)
        .run(cluster)
        .read_bw()
}

fn main() {
    let mut t = Table::new(vec!["workers", "round-robin", "least-loaded"]);
    for workers in [1usize, 2, 4, 8, 16] {
        t.row(vec![
            workers.to_string(),
            fmt_bandwidth(run(workers, Dispatch::RoundRobin)),
            fmt_bandwidth(run(workers, Dispatch::LeastLoaded)),
        ]);
    }
    println!(
        "Server ablation — CommitFS CC-R 8KiB reads, 8 nodes x 12 procs\n\
         (expected: flat beyond a few workers — the serial master\n\
         dispatch is the bottleneck, matching the paper's Fig 5/6 story)\n\n{}",
        t.render()
    );
}
