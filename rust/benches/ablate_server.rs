//! Ablation — global-server design choices (§5.1.2): worker-pool width
//! and dispatch policy. The paper's server uses a master thread with
//! round-robin FIFO workers; this bench shows (a) the master, not the
//! workers, is the choke point for commit's per-read queries, and
//! (b) round-robin vs least-loaded dispatch barely matters because
//! query service times are uniform. (`ablate_sharding` shows the fix:
//! multiply the masters, not the workers.)
//!
//! `--json` additionally writes target/results/BENCH_ablate_server.json.

use pscnf::coordinator::maybe_write_bench_json;
use pscnf::fs::FsKind;
use pscnf::sim::{Cluster, Dispatch, NetParams, ServerParams, SsdParams, UpfsParams};
use pscnf::util::json::Json;
use pscnf::util::table::Table;
use pscnf::util::units::fmt_bandwidth;
use pscnf::workload::{Config, SyntheticDriver};

fn run(workers: usize, dispatch: Dispatch) -> f64 {
    let nodes = 8;
    let params = Config::CcR.params(nodes, 12, 8 << 10, 10, 7);
    let server = ServerParams {
        workers,
        dispatch,
        ..ServerParams::catalyst()
    };
    let cluster = Cluster::new(
        nodes,
        SsdParams::catalyst(),
        NetParams::ib_qdr(),
        server,
        UpfsParams::catalyst_lustre(),
        99,
    );
    SyntheticDriver::new(FsKind::Commit, params)
        .run(cluster)
        .read_bw()
}

fn main() {
    let mut t = Table::new(vec!["workers", "round-robin", "least-loaded"]);
    let mut rows = Vec::new();
    for workers in [1usize, 2, 4, 8, 16] {
        let rr = run(workers, Dispatch::RoundRobin);
        let ll = run(workers, Dispatch::LeastLoaded);
        t.row(vec![
            workers.to_string(),
            fmt_bandwidth(rr),
            fmt_bandwidth(ll),
        ]);
        rows.push((workers, rr, ll));
    }
    println!(
        "Server ablation — CommitFS CC-R 8KiB reads, 8 nodes x 12 procs\n\
         (expected: flat beyond a few workers — the serial master\n\
         dispatch is the bottleneck, matching the paper's Fig 5/6 story)\n\n{}",
        t.render()
    );

    let mut payload = Json::obj();
    payload
        .set("workload", Config::CcR.name())
        .set("fs", FsKind::Commit.name())
        .set("access_bytes", 8u64 << 10)
        .set(
            "cells",
            Json::Arr(
                rows.iter()
                    .map(|&(workers, rr, ll)| {
                        let mut o = Json::obj();
                        o.set("workers", workers)
                            .set("round_robin_bw", rr)
                            .set("least_loaded_bw", ll);
                        o
                    })
                    .collect(),
            ),
        );
    maybe_write_bench_json("ablate_server", payload);
}
