//! Ablation — global-server design choices (§5.1.2): worker-pool width
//! and dispatch policy. The paper's server uses a master thread with
//! round-robin FIFO workers; this bench shows (a) the master, not the
//! workers, is the choke point for commit's per-read queries (bandwidth
//! stays flat beyond a few workers), and (b) round-robin vs
//! least-loaded dispatch barely matters because query service times are
//! uniform. (`ablate_sharding` shows the fix: multiply the masters, not
//! the workers.)
//!
//! Thin wrapper over the `ablate_server` family of the bench registry
//! (scenario scale tags are `w<workers>.<rr|ll>`). `--json`
//! additionally writes `target/results/BENCH_ablate_server.json`.

fn main() {
    pscnf::bench::family_main("ablate_server");
}
