//! Ablation — commit granularity (§2.3.1): committing every write
//! (fine, byte-range) vs one commit per phase (coarse). The paper notes
//! finer granularity "may add additional overhead if used in a
//! superfluous way" — here is that overhead, as a function of scale,
//! for the CN-W small-write workload where it is purely superfluous.

use pscnf::basefs::DesFabric;
use pscnf::config::Testbed;
use pscnf::fs::{CommitFs, FsKind};
use pscnf::sim::{Driver, Engine, Ns, SimOp};
use pscnf::util::table::Table;
use pscnf::util::units::fmt_bandwidth;
use pscnf::workload::{Config, SyntheticDriver};
use std::collections::VecDeque;

/// CN-W with a commit after EVERY write (the superfluous pattern).
struct FineGrainedDriver {
    fabric: DesFabric,
    fs: Vec<CommitFs>,
    file: u64,
    plan: Vec<Vec<u64>>,
    next: Vec<usize>,
    pending: Vec<VecDeque<SimOp>>,
    payload: Vec<u8>,
    size: u64,
    done_at: Ns,
}

impl FineGrainedDriver {
    fn new(nodes: usize, ppn: usize, size: u64, m: usize) -> Self {
        let params = Config::CnW.params(nodes, ppn, size, m, 7);
        let nranks = params.nranks();
        let node_of: Vec<usize> = (0..nranks).map(|r| r / ppn).collect();
        let fabric = DesFabric::new_phantom(node_of);
        let mut fs: Vec<CommitFs> = (0..nranks)
            .map(|r| CommitFs::new(r as u32, fabric.bb_of(r as u32)))
            .collect();
        let mut fabric = fabric;
        let mut file = 0;
        for f in fs.iter_mut() {
            file = pscnf::fs::WorkloadFs::open(f, &mut fabric, "/fine.dat");
        }
        let plan: Vec<Vec<u64>> = (0..nranks).map(|r| params.write_offsets(r)).collect();
        Self {
            fabric,
            fs,
            file,
            plan,
            next: vec![0; nranks],
            pending: (0..nranks).map(|_| VecDeque::new()).collect(),
            payload: vec![0u8; size as usize],
            size,
            done_at: Ns::ZERO,
        }
    }
}

impl Driver for FineGrainedDriver {
    fn next_op(&mut self, rank: usize, now: Ns) -> SimOp {
        loop {
            if let Some(op) = self.pending[rank].pop_front() {
                return op;
            }
            let i = self.next[rank];
            if i < self.plan[rank].len() {
                let off = self.plan[rank][i];
                CommitFs::write_at(&mut self.fs[rank], &mut self.fabric, self.file, off, &self.payload)
                    .unwrap();
                self.fs[rank]
                    .commit_range(&mut self.fabric, self.file, off, self.size)
                    .unwrap();
                self.next[rank] = i + 1;
                while let Some(op) = self.fabric.pop_cost(rank as u32) {
                    self.pending[rank].push_back(op);
                }
            } else {
                self.done_at = self.done_at.max(now);
                return SimOp::Done;
            }
        }
    }
}

fn main() {
    let (ppn, size, m) = (12usize, 8u64 << 10, 10usize);
    let mut t = Table::new(vec!["nodes", "coarse (1 commit)", "fine (commit/write)", "penalty"]);
    for nodes in [2usize, 4, 8, 16] {
        // Coarse: the normal CommitFS CN-W path.
        let coarse = SyntheticDriver::new(FsKind::Commit, Config::CnW.params(nodes, ppn, size, m, 7))
            .run(Testbed::Catalyst.cluster(nodes, 9));
        let coarse_bw = coarse.write_bw();
        // Fine: commit after every write.
        let mut fine = FineGrainedDriver::new(nodes, ppn, size, m);
        let node_of: Vec<usize> = (0..nodes * ppn).map(|r| r / ppn).collect();
        let mut engine = Engine::new(Testbed::Catalyst.cluster(nodes, 9), node_of);
        engine.run(&mut fine).unwrap();
        let total = (nodes * ppn * m) as u64 * size;
        let fine_bw = total as f64 / fine.done_at.as_secs_f64();
        t.row(vec![
            nodes.to_string(),
            fmt_bandwidth(coarse_bw),
            fmt_bandwidth(fine_bw),
            format!("{:.2}x", coarse_bw / fine_bw),
        ]);
    }
    println!(
        "Commit-granularity ablation — CN-W, 8KiB writes, ppn=12, m=10\n\
         (expected: superfluous per-write commits cost increasingly more\n\
         as the commit RPCs pile onto the global server)\n\n{}",
        t.render()
    );
}
