//! Ablation — commit granularity (§2.3.1): committing every write
//! (fine, byte-range) vs one commit per phase (coarse). The paper notes
//! finer granularity "may add additional overhead if used in a
//! superfluous way" — here is that overhead, as a function of scale,
//! for the CN-W small-write workload where it is purely superfluous:
//! compare the `CN-W.coarse` and `CN-W.fine` rows at each node count.
//!
//! Thin wrapper over the `ablate_granularity` family of the bench
//! registry (the fine-grained driver lives in `bench::runner`).
//! `--json` additionally writes
//! `target/results/BENCH_ablate_granularity.json`.

fn main() {
    pscnf::bench::family_main("ablate_granularity");
}
