//! Fig 3 — write bandwidth of CN-W and SN-W with 8 MiB and 8 KiB access
//! sizes, 1–16 nodes × 12 procs, on the simulated Catalyst testbed,
//! under **all four** consistency models (the paper plots commit vs
//! session; posix and mpiio complete the matrix).
//!
//! Paper shape to reproduce (§6.1.1):
//! - CN-W ≈ SN-W (BB buffering converts N-1 to N-N writes);
//! - session ≈ commit (session_open is a no-op on an empty FS, close
//!   does the same work as commit);
//! - 8 MiB writes reach the SSD peak (~1 GB/s per node), 8 KiB writes
//!   fall well short of saturation.
//!
//! Thin wrapper over the `fig3` family of the bench registry
//! (`pscnf bench --filter fig3` runs the same cells). `--json`
//! additionally writes `target/results/BENCH_fig3.json`.

fn main() {
    pscnf::bench::family_main("fig3");
}
