//! Fig 3 — write bandwidth of CN-W and SN-W with 8 MiB and 8 KiB access
//! sizes, 1–16 nodes × 12 procs, commit vs session consistency, on the
//! simulated Catalyst testbed.
//!
//! Paper shape to reproduce (§6.1.1):
//! - CN-W ≈ SN-W (BB buffering converts N-1 to N-N writes);
//! - session ≈ commit (session_open is a no-op on an empty FS, close
//!   does the same work as commit);
//! - 8 MiB writes reach the SSD peak (~1 GB/s per node), 8 KiB writes
//!   fall well short of saturation.

use pscnf::config::Testbed;
use pscnf::coordinator::{render_sweep, sweep_synthetic, write_results};
use pscnf::fs::FsKind;
use pscnf::util::json::Json;
use pscnf::util::units::fmt_bytes;
use pscnf::workload::Config;

fn main() {
    let nodes = [1usize, 2, 4, 8, 16];
    let fs = [FsKind::Commit, FsKind::Session];
    let mut all = Json::obj();
    for config in [Config::CnW, Config::SnW] {
        for access in [8u64 << 20, 8 << 10] {
            let cells = sweep_synthetic(
                config,
                access,
                &nodes,
                &fs,
                12,
                10,
                5,
                Testbed::Catalyst,
                true,
            );
            println!(
                "{}\n",
                render_sweep(
                    &format!(
                        "Fig 3 — {} write bandwidth, access={} (ppn=12, m=10)",
                        config.name(),
                        fmt_bytes(access)
                    ),
                    &cells
                )
            );
            all.set(
                &format!("{}_{}", config.name(), fmt_bytes(access)),
                Json::Arr(cells.iter().map(|c| c.to_json()).collect()),
            );
        }
    }
    write_results("fig3_write_bw", all);
    println!("results: target/results/fig3_write_bw.json");
}
