//! Race-detector throughput pricing — what `pscnf check` costs per
//! operation checked. Each cell records its synthetic two-phase CC-R
//! formal trace once (deterministic in the repeat-0 seed) and then
//! times the frontier detector (`model::check::detect_indexed`) over
//! it, happens-before and interval index rebuilt inside the timed
//! region — exactly the per-model cost of `pscnf check <trace>`. The
//! headline metric is `ops_checked_per_sec` (wall clock, best of
//! repeats, like `perf_hotpath`); the race verdict rides the record's
//! params so a baseline diff also catches a detector that gets faster
//! by getting wrong.
//!
//! Thin wrapper over the `check_matrix` family of the bench registry
//! (small gated cells at n2, larger ungated ones at n8). `--json`
//! additionally writes `target/results/BENCH_check_matrix.json`.

fn main() {
    pscnf::bench::family_main("check_matrix");
}
